//! Ring allreduce with real summation — the collective the trainer uses to
//! combine per-worker gradients.
//!
//! The implementation follows the classic two-phase schedule (Baidu ring):
//! `W-1` reduce-scatter steps followed by `W-1` all-gather steps over `W`
//! equal chunks.  Communication here is memory movement between worker
//! buffers (the workers are in-process), but the *schedule* is the real
//! one: each phase moves exactly the chunks a wire implementation would,
//! which is what the cost model (`collective::cost`) prices and what the
//! allreduce bench measures.
//!
//! Numerical note: chunk c of every worker is reduced in the same ring
//! order regardless of W, so results are deterministic; f32 accumulation
//! order differs from a naive sequential sum by design (as on real rings).
//!
//! [`ring_allreduce_pooled`] is the chunk-parallel variant: within each ring
//! step the W per-chunk copies/sums touch disjoint buffer regions, so they
//! run concurrently on a [`ThreadPool`].  Element order within every chunk
//! is unchanged, so the pooled result is bit-identical to the serial one
//! (asserted by tests here and in `tests/proptests.rs`).

use crate::util::pool::ThreadPool;

/// In-place ring allreduce (sum) across `bufs` (one buffer per worker).
/// All buffers must be the same length.  After return, every buffer holds
/// the element-wise sum.
pub fn ring_allreduce(bufs: &mut [Vec<f32>]) {
    let w = bufs.len();
    assert!(w > 0, "no workers");
    let n = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == n), "buffer length mismatch");
    if w == 1 || n == 0 {
        return;
    }

    // chunk boundaries: chunk c covers [starts[c], starts[c+1])
    let starts: Vec<usize> = (0..=w).map(|c| c * n / w).collect();

    // Phase 1 — reduce-scatter: after step s, worker (c + s + 1) mod w holds
    // the partial sum of chunk c over s+2 workers.  After w-1 steps, worker
    // (c + w - 1) mod w owns the full sum of chunk c.
    for s in 0..w - 1 {
        for c in 0..w {
            let src = (c + s) % w;
            let dst = (c + s + 1) % w;
            let (lo, hi) = (starts[c], starts[c + 1]);
            // sum src's chunk into dst's chunk
            let (a, b) = split_two(bufs, src, dst);
            for i in lo..hi {
                b[i] += a[i];
            }
        }
    }

    // Phase 2 — all-gather: owner of each reduced chunk circulates it.
    for s in 0..w - 1 {
        for c in 0..w {
            let src = (c + w - 1 + s) % w;
            let dst = (c + w + s) % w;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let (a, b) = split_two(bufs, src, dst);
            b[lo..hi].copy_from_slice(&a[lo..hi]);
        }
    }
}

/// Below this buffer length the pool's per-step spawn cost exceeds the
/// chunk work; [`ring_allreduce_pooled`] falls back to the serial ring
/// (identical results either way).
pub const POOLED_MIN_ELEMS: usize = 1 << 12;

/// Chunk-parallel ring allreduce: the same two-phase schedule as
/// [`ring_allreduce`], with the `W` per-chunk operations of every ring step
/// executed concurrently on `pool`.  Falls back to the serial path for a
/// width-1 pool, small buffers or degenerate inputs; results are
/// bit-identical either way.
pub fn ring_allreduce_pooled(bufs: &mut [Vec<f32>], pool: &ThreadPool) {
    let w = bufs.len();
    assert!(w > 0, "no workers");
    let n = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == n), "buffer length mismatch");
    if pool.threads() <= 1 || w < 2 || n < POOLED_MIN_ELEMS {
        ring_allreduce(bufs);
        return;
    }
    let starts: Vec<usize> = (0..=w).map(|c| c * n / w).collect();

    // Phase 1 — reduce-scatter, chunk-parallel within each ring step.
    for s in 0..w - 1 {
        let mut tasks = ring_step_tasks(bufs, &starts, s, true);
        pool.map_mut(&mut tasks, |t| {
            for (d, x) in t.dst.iter_mut().zip(t.src.iter()) {
                *d += *x;
            }
        });
    }

    // Phase 2 — all-gather, chunk-parallel within each ring step.
    for s in 0..w - 1 {
        let mut tasks = ring_step_tasks(bufs, &starts, s, false);
        pool.map_mut(&mut tasks, |t| t.dst.copy_from_slice(t.src));
    }
}

/// One parallel unit of a ring step: move/accumulate `src` into `dst`.
/// The slices of different tasks never overlap (distinct chunks of distinct
/// buffers), which is what makes the step safely chunk-parallel.
struct ChunkTask<'a> {
    src: &'a [f32],
    dst: &'a mut [f32],
}

/// Carve the per-chunk (src, dst) slice pairs for ring step `s`.
///
/// In the reduce-scatter phase buffer `b` sends (is read at) chunk
/// `(b - s) mod w` and receives (is written at) chunk `(b - s - 1) mod w`;
/// in the all-gather phase it sends chunk `(b + 1 - s) mod w` and receives
/// chunk `(b - s) mod w` — the chunk↔buffer mapping of the classic
/// schedule, reindexed per buffer so each buffer is borrowed exactly once.
fn ring_step_tasks<'a>(
    bufs: &'a mut [Vec<f32>],
    starts: &[usize],
    s: usize,
    reduce: bool,
) -> Vec<ChunkTask<'a>> {
    let w = bufs.len();
    let mut srcs: Vec<Option<&[f32]>> = (0..w).map(|_| None).collect();
    let mut dsts: Vec<Option<&mut [f32]>> = (0..w).map(|_| None).collect();
    for (b, buf) in bufs.iter_mut().enumerate() {
        let (c_read, c_write) = if reduce {
            ((b + w - s) % w, (b + w - s - 1) % w)
        } else {
            ((b + w + 1 - s) % w, (b + w - s) % w)
        };
        let (rd, wr) = carve(
            buf,
            starts[c_read]..starts[c_read + 1],
            starts[c_write]..starts[c_write + 1],
        );
        srcs[c_read] = Some(rd);
        dsts[c_write] = Some(wr);
    }
    srcs.into_iter()
        .zip(dsts)
        .map(|(src, dst)| ChunkTask {
            src: src.expect("ring chunk without a source"),
            dst: dst.expect("ring chunk without a destination"),
        })
        .collect()
}

/// Split one buffer into a shared slice over `read` and a mutable slice
/// over `write`.  The ranges are distinct chunks, so non-empty ranges never
/// overlap; empty ranges may sit anywhere.
fn carve<'a>(
    buf: &'a mut [f32],
    read: std::ops::Range<usize>,
    write: std::ops::Range<usize>,
) -> (&'a [f32], &'a mut [f32]) {
    if write.is_empty() {
        return (&buf[read], &mut []);
    }
    if read.is_empty() {
        return (&[], &mut buf[write]);
    }
    if read.start < write.start {
        let (lo, hi) = buf.split_at_mut(write.start);
        (&lo[read], &mut hi[..write.end - write.start])
    } else {
        let (lo, hi) = buf.split_at_mut(read.start);
        (&hi[..read.end - read.start], &mut lo[write])
    }
}

/// Allreduce then divide by the worker count (gradient averaging).
pub fn ring_allreduce_avg(bufs: &mut [Vec<f32>]) {
    let w = bufs.len() as f32;
    ring_allreduce(bufs);
    for b in bufs.iter_mut() {
        for x in b.iter_mut() {
            *x /= w;
        }
    }
}

/// Borrow two distinct workers' buffers mutably.
fn split_two(bufs: &mut [Vec<f32>], src: usize, dst: usize) -> (&[f32], &mut [f32]) {
    assert_ne!(src, dst);
    if src < dst {
        let (l, r) = bufs.split_at_mut(dst);
        (&l[src], &mut r[0])
    } else {
        let (l, r) = bufs.split_at_mut(src);
        (&r[0], &mut l[dst])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn check_sum(w: usize, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let mut bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
            .collect();
        let expect: Vec<f32> = (0..n)
            .map(|i| bufs.iter().map(|b| b[i]).sum())
            .collect();
        ring_allreduce(&mut bufs);
        for b in &bufs {
            for (got, want) in b.iter().zip(&expect) {
                assert!(
                    (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                    "{got} vs {want} (w={w} n={n})"
                );
            }
        }
    }

    #[test]
    fn sums_match_many_shapes() {
        for (w, n) in [(1, 8), (2, 10), (3, 7), (4, 64), (8, 1000), (5, 3)] {
            check_sum(w, n, (w * 1000 + n) as u64);
        }
    }

    #[test]
    fn n_smaller_than_workers() {
        // degenerate chunking: some chunks are empty
        check_sum(8, 3, 42);
    }

    #[test]
    fn avg_divides() {
        let mut bufs = vec![vec![2.0f32; 4], vec![4.0f32; 4]];
        ring_allreduce_avg(&mut bufs);
        for b in &bufs {
            assert_eq!(b, &vec![3.0f32; 4]);
        }
    }

    #[test]
    fn pooled_matches_serial_bit_for_bit() {
        for (w, n, threads) in [
            // below POOLED_MIN_ELEMS: exercises the serial fallback
            (1, 8, 4),
            (2, 10, 4),
            (8, 3, 4), // empty chunks: n < w
            // above: exercises the chunk-parallel path proper
            (2, 5000, 4),
            (3, 4099, 2), // chunk boundaries straddle odd offsets
            (4, 65536, 8),
            (8, 30011, 4),
        ] {
            let mut rng = Rng::new((w * 1009 + n * 31 + threads) as u64);
            let template: Vec<Vec<f32>> = (0..w)
                .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
                .collect();
            let mut serial = template.clone();
            let mut pooled = template;
            ring_allreduce(&mut serial);
            ring_allreduce_pooled(&mut pooled, &ThreadPool::new(threads));
            assert_eq!(serial, pooled, "w={w} n={n} threads={threads}");
        }
    }

    #[test]
    fn pooled_width1_takes_serial_path() {
        let mut a = vec![vec![1.0f32; 6], vec![2.0f32; 6]];
        let mut b = a.clone();
        ring_allreduce(&mut a);
        ring_allreduce_pooled(&mut b, &ThreadPool::new(1));
        assert_eq!(a, b);
    }

    #[test]
    fn all_workers_agree() {
        let mut rng = Rng::new(9);
        let mut bufs: Vec<Vec<f32>> =
            (0..6).map(|_| (0..50).map(|_| rng.normal_f32()).collect()).collect();
        ring_allreduce(&mut bufs);
        for w in 1..6 {
            assert_eq!(bufs[0], bufs[w], "worker {w} disagrees");
        }
    }
}
