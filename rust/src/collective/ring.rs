//! Ring allreduce with real summation — the collective the trainer uses to
//! combine per-worker gradients.
//!
//! The implementation is the composition of the two ring phases from
//! [`super::reduce_scatter`]: `W-1` reduce-scatter steps followed by `W-1`
//! all-gather steps over `W` equal chunks (the classic Baidu schedule).
//! Communication here is memory movement between worker buffers (the
//! workers are in-process), but the *schedule* is the real one: each phase
//! moves exactly the chunks a wire implementation would, which is what the
//! cost model (`collective::cost`) prices and what the allreduce bench
//! measures.
//!
//! Numerical note: chunk c of every worker is reduced in the same ring
//! order regardless of W, so results are deterministic; f32 accumulation
//! order differs from a naive sequential sum by design (as on real rings).
//!
//! [`ring_allreduce_pooled`] is the chunk-parallel variant: within each ring
//! step the W per-chunk copies/sums touch disjoint buffer regions, so they
//! run concurrently as one [`ThreadPool`] region per step — `2(W-1)` cheap
//! regions per allreduce on the persistent pool's parked workers (the
//! per-call-spawn cost this schedule used to pay per step is what the
//! `allreduce` bench's spawn column measures).  Element order within every
//! chunk is unchanged, so the pooled result is bit-identical to the serial
//! one (asserted by tests here and in `tests/proptests.rs`).

use crate::precision::DType;
use crate::trace;
use crate::util::pool::ThreadPool;

use super::half::ring_allreduce_wire_bytes;
use super::reduce_scatter::{
    ring_all_gather_at, ring_all_gather_pooled, ring_chunk_starts,
    ring_reduce_scatter_at, ring_reduce_scatter_pooled,
};

pub use super::reduce_scatter::POOLED_MIN_ELEMS;

/// In-place ring allreduce (sum) across `bufs` (one buffer per worker).
/// All buffers must be the same length.  After return, every buffer holds
/// the element-wise sum.
pub fn ring_allreduce(bufs: &mut [Vec<f32>]) {
    let w = bufs.len();
    assert!(w > 0, "no workers");
    let n = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == n), "buffer length mismatch");
    let _sp = trace::span_detail(
        trace::CAT_COMM,
        "ring_allreduce",
        ring_allreduce_wire_bytes(w, n, DType::F32),
    );
    if w == 1 || n == 0 {
        return;
    }
    let starts = ring_chunk_starts(w, n);
    ring_reduce_scatter_at(bufs, &starts);
    ring_all_gather_at(bufs, &starts);
}

/// Chunk-parallel ring allreduce: the same two-phase schedule as
/// [`ring_allreduce`], with the `W` per-chunk operations of every ring step
/// executed concurrently on `pool`.  Falls back to the serial path for a
/// width-1 pool, small buffers or degenerate inputs; results are
/// bit-identical either way.
pub fn ring_allreduce_pooled(bufs: &mut [Vec<f32>], pool: &ThreadPool) {
    let w = bufs.len();
    let n = bufs.first().map_or(0, |b| b.len());
    let _sp = trace::span_detail(
        trace::CAT_COMM,
        "ring_allreduce_pooled",
        ring_allreduce_wire_bytes(w, n, DType::F32),
    );
    ring_reduce_scatter_pooled(bufs, pool);
    ring_all_gather_pooled(bufs, pool);
}

/// Allreduce then divide by the worker count (gradient averaging).
pub fn ring_allreduce_avg(bufs: &mut [Vec<f32>]) {
    let w = bufs.len() as f32;
    ring_allreduce(bufs);
    for b in bufs.iter_mut() {
        for x in b.iter_mut() {
            *x /= w;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn check_sum(w: usize, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let mut bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
            .collect();
        let expect: Vec<f32> = (0..n)
            .map(|i| bufs.iter().map(|b| b[i]).sum())
            .collect();
        ring_allreduce(&mut bufs);
        for b in &bufs {
            for (got, want) in b.iter().zip(&expect) {
                assert!(
                    (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                    "{got} vs {want} (w={w} n={n})"
                );
            }
        }
    }

    #[test]
    fn sums_match_many_shapes() {
        for (w, n) in [(1, 8), (2, 10), (3, 7), (4, 64), (8, 1000), (5, 3)] {
            check_sum(w, n, (w * 1000 + n) as u64);
        }
    }

    #[test]
    fn n_smaller_than_workers() {
        // degenerate chunking: some chunks are empty
        check_sum(8, 3, 42);
    }

    #[test]
    fn avg_divides() {
        let mut bufs = vec![vec![2.0f32; 4], vec![4.0f32; 4]];
        ring_allreduce_avg(&mut bufs);
        for b in &bufs {
            assert_eq!(b, &vec![3.0f32; 4]);
        }
    }

    #[test]
    fn pooled_matches_serial_bit_for_bit() {
        for (w, n, threads) in [
            // below POOLED_MIN_ELEMS: exercises the serial fallback
            (1, 8, 4),
            (2, 10, 4),
            (8, 3, 4), // empty chunks: n < w
            // above: exercises the chunk-parallel path proper
            (2, 5000, 4),
            (3, 4099, 2), // chunk boundaries straddle odd offsets
            (4, 65536, 8),
            (8, 30011, 4),
        ] {
            let mut rng = Rng::new((w * 1009 + n * 31 + threads) as u64);
            let template: Vec<Vec<f32>> = (0..w)
                .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
                .collect();
            let mut serial = template.clone();
            let mut pooled = template;
            ring_allreduce(&mut serial);
            ring_allreduce_pooled(&mut pooled, &ThreadPool::new(threads));
            assert_eq!(serial, pooled, "w={w} n={n} threads={threads}");
        }
    }

    #[test]
    fn pooled_width1_takes_serial_path() {
        let mut a = vec![vec![1.0f32; 6], vec![2.0f32; 6]];
        let mut b = a.clone();
        ring_allreduce(&mut a);
        ring_allreduce_pooled(&mut b, &ThreadPool::new(1));
        assert_eq!(a, b);
    }

    #[test]
    fn all_workers_agree() {
        let mut rng = Rng::new(9);
        let mut bufs: Vec<Vec<f32>> =
            (0..6).map(|_| (0..50).map(|_| rng.normal_f32()).collect()).collect();
        ring_allreduce(&mut bufs);
        for w in 1..6 {
            assert_eq!(bufs[0], bufs[w], "worker {w} disagrees");
        }
    }
}
