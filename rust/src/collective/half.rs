//! Half-precision wire variants of the ring collectives — the fp16/bf16
//! gradient exchange of the paper's 54-minute run, where halving wire
//! bytes halves the β-term the cost model prices.
//!
//! Schedule and chunk grid are exactly the f32 ring's
//! ([`ring_chunk_starts`], same `W-1`-step phases); only what crosses the
//! wire changes:
//!
//! * **reduce-scatter** — every hop's outgoing chunk crosses the wire as
//!   packed half data (2 bytes/element), and the receiver *accumulates in
//!   f32*: `dst[i] += dq(wire[i])`.  In process both halves run as one
//!   fused SIMD kernel ([`quantize_accumulate`]) — quantize and widen stay
//!   in registers, a hop allocates nothing.  Chunk `c` is still
//!   reduced in worker order `c, c+1, …` regardless of schedule, so for
//!   fixed inputs the result is a deterministic function — the pooled
//!   variant is bit-identical to the serial one (property-tested).
//! * **all-gather** — each reduced chunk crosses the wire once as a
//!   `HalfVec`; the owner *also adopts* the dequantized wire value, so
//!   every replica ends bit-identical (a replicated trainer requires it).
//!   Re-quantizing an already-quantized value is the identity
//!   (`q ∘ dq ∘ q = q`), so multi-hop forwarding adds no further loss.
//!
//! `wire == DType::F32` is the identity wire format: these entry points
//! delegate straight to the exact f32 schedule, so routing the trainer
//! through them leaves the f32 path exact-bit unchanged.
//!
//! Every function returns the total bytes its schedule put on the wire
//! (summed over all endpoints) — `(W-1) · N · bytes/elem` per phase — so
//! the `mixed_precision` bench can assert the fp16 wire moves half the
//! fp32 bytes without re-deriving the schedule.

use crate::precision::{quantize_accumulate, round_trip_slice, DType};
use crate::trace;
use crate::util::pool::ThreadPool;

use super::reduce_scatter::{
    check_bufs, chunk_owner, ring_all_gather, ring_all_gather_at, ring_all_gather_pooled,
    ring_chunk_starts, ring_reduce_scatter, ring_reduce_scatter_pooled, ring_step_tasks,
    split_two, POOLED_MIN_ELEMS,
};
use super::ring::{ring_allreduce, ring_allreduce_pooled};

/// Bytes one ring phase (reduce-scatter *or* all-gather) puts on the wire,
/// summed over all endpoints: each of the `W-1` steps moves every chunk
/// once, i.e. `N` elements per step.
pub fn ring_phase_wire_bytes(w: usize, n: usize, wire: DType) -> u64 {
    if w <= 1 {
        return 0;
    }
    (w as u64 - 1) * n as u64 * wire.bytes() as u64
}

/// Wire bytes of the full allreduce (both phases).
pub fn ring_allreduce_wire_bytes(w: usize, n: usize, wire: DType) -> u64 {
    2 * ring_phase_wire_bytes(w, n, wire)
}

/// Reduce-scatter with half-precision wire chunks and f32 accumulation.
/// Postcondition matches [`ring_reduce_scatter`]: chunk `c`'s (f32) sum
/// sits at [`chunk_owner`]`(c, w)`.  Returns wire bytes moved.
pub fn ring_reduce_scatter_half(bufs: &mut [Vec<f32>], wire: DType) -> u64 {
    let (w, n) = check_bufs(bufs);
    let bytes = ring_phase_wire_bytes(w, n, wire);
    let _sp = trace::span_detail(trace::CAT_COMM, "ring_reduce_scatter_half", bytes);
    if !wire.is_half() {
        ring_reduce_scatter(bufs);
        return bytes;
    }
    if w == 1 || n == 0 {
        return bytes;
    }
    let starts = ring_chunk_starts(w, n);
    for s in 0..w - 1 {
        for c in 0..w {
            let src = (c + s) % w;
            let dst = (c + s + 1) % w;
            let (lo, hi) = (starts[c], starts[c + 1]);
            if lo == hi {
                continue;
            }
            let (a, b) = split_two(bufs, src, dst);
            // wire boundary: the outgoing chunk is quantized to half and
            // the receiver accumulates the widened image in f32 — one
            // fused batch kernel, no packed intermediate
            quantize_accumulate(wire, &a[lo..hi], &mut b[lo..hi]);
        }
    }
    bytes
}

/// Chunk-parallel [`ring_reduce_scatter_half`]: the `W` per-chunk
/// quantize/accumulate ops of every ring step run concurrently on `pool`
/// (disjoint buffer regions).  Bit-identical to the serial path; falls
/// back to it for width-1 pools, small buffers or degenerate inputs.
pub fn ring_reduce_scatter_half_pooled(
    bufs: &mut [Vec<f32>],
    wire: DType,
    pool: &ThreadPool,
) -> u64 {
    let (w, n) = check_bufs(bufs);
    let _sp = trace::span_detail(
        trace::CAT_COMM,
        "ring_reduce_scatter_half_pooled",
        ring_phase_wire_bytes(w, n, wire),
    );
    if !wire.is_half() {
        ring_reduce_scatter_pooled(bufs, pool);
        return ring_phase_wire_bytes(w, n, wire);
    }
    if pool.threads() <= 1 || w < 2 || n < POOLED_MIN_ELEMS {
        return ring_reduce_scatter_half(bufs, wire);
    }
    let starts = ring_chunk_starts(w, n);
    for s in 0..w - 1 {
        let mut tasks = ring_step_tasks(bufs, &starts, s, true);
        pool.map_mut(&mut tasks, |t| quantize_accumulate(wire, t.src, t.dst));
    }
    ring_phase_wire_bytes(w, n, wire)
}

/// All-gather with half-precision wire chunks: each owner's reduced chunk
/// is quantized once at the wire boundary, the owner adopts the
/// dequantized value, and the pure-copy ring circulates it — every
/// replica (owner included) ends bit-identical.  Returns wire bytes.
pub fn ring_all_gather_half(bufs: &mut [Vec<f32>], wire: DType) -> u64 {
    let (w, n) = check_bufs(bufs);
    let bytes = ring_phase_wire_bytes(w, n, wire);
    let _sp = trace::span_detail(trace::CAT_COMM, "ring_all_gather_half", bytes);
    if !wire.is_half() {
        ring_all_gather(bufs);
        return bytes;
    }
    if w == 1 || n == 0 {
        return bytes;
    }
    let starts = ring_chunk_starts(w, n);
    round_owner_chunks(bufs, &starts, wire);
    ring_all_gather_at(bufs, &starts);
    bytes
}

/// Pooled [`ring_all_gather_half`]; bit-identical to the serial path.
pub fn ring_all_gather_half_pooled(bufs: &mut [Vec<f32>], wire: DType, pool: &ThreadPool) -> u64 {
    let (w, n) = check_bufs(bufs);
    let _sp = trace::span_detail(
        trace::CAT_COMM,
        "ring_all_gather_half_pooled",
        ring_phase_wire_bytes(w, n, wire),
    );
    if !wire.is_half() {
        ring_all_gather_pooled(bufs, pool);
        return ring_phase_wire_bytes(w, n, wire);
    }
    if pool.threads() <= 1 || w < 2 || n < POOLED_MIN_ELEMS {
        return ring_all_gather_half(bufs, wire);
    }
    let starts = ring_chunk_starts(w, n);
    // one region rounds every owner's chunk (disjoint: one owned chunk per
    // buffer), then the pooled pure-copy gather circulates the values
    let mut tasks: Vec<OwnedChunk<'_>> = bufs
        .iter_mut()
        .enumerate()
        .map(|(b, buf)| {
            let c = (b + 1) % w; // chunk_owner(c, w) == b
            debug_assert_eq!(chunk_owner(c, w), b);
            OwnedChunk { seg: &mut buf[starts[c]..starts[c + 1]] }
        })
        .collect();
    pool.map_mut(&mut tasks, |t| round_segment(t.seg, wire));
    drop(tasks);
    ring_all_gather_pooled(bufs, pool);
    ring_phase_wire_bytes(w, n, wire)
}

struct OwnedChunk<'a> {
    seg: &'a mut [f32],
}

/// Quantize a segment to the wire format and adopt the dequantized image —
/// the owner-side half of the gather's wire boundary.
fn round_segment(seg: &mut [f32], wire: DType) {
    round_trip_slice(wire, seg);
}

fn round_owner_chunks(bufs: &mut [Vec<f32>], starts: &[usize], wire: DType) {
    let w = bufs.len();
    for c in 0..w {
        let o = chunk_owner(c, w);
        round_segment(&mut bufs[o][starts[c]..starts[c + 1]], wire);
    }
}

/// Half-wire allreduce: [`ring_reduce_scatter_half`] then
/// [`ring_all_gather_half`].  Every worker ends with the same bits.
pub fn ring_allreduce_half(bufs: &mut [Vec<f32>], wire: DType) -> u64 {
    if !wire.is_half() {
        let (w, n) = check_bufs(bufs);
        ring_allreduce(bufs);
        return ring_allreduce_wire_bytes(w, n, wire);
    }
    ring_reduce_scatter_half(bufs, wire) + ring_all_gather_half(bufs, wire)
}

/// Pooled [`ring_allreduce_half`]; bit-identical to the serial path.
pub fn ring_allreduce_half_pooled(bufs: &mut [Vec<f32>], wire: DType, pool: &ThreadPool) -> u64 {
    if !wire.is_half() {
        let (w, n) = check_bufs(bufs);
        ring_allreduce_pooled(bufs, pool);
        return ring_allreduce_wire_bytes(w, n, wire);
    }
    ring_reduce_scatter_half_pooled(bufs, wire, pool)
        + ring_all_gather_half_pooled(bufs, wire, pool)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_bufs(w: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..w).map(|_| (0..n).map(|_| rng.normal_f32()).collect()).collect()
    }

    #[test]
    fn f32_wire_is_the_exact_legacy_path() {
        for (w, n) in [(1, 8), (3, 100), (4, 5000)] {
            let template = random_bufs(w, n, (w * 100 + n) as u64);
            let mut legacy = template.clone();
            let mut wirev = template;
            ring_allreduce(&mut legacy);
            let bytes = ring_allreduce_half(&mut wirev, DType::F32);
            assert_eq!(legacy, wirev, "w={w} n={n}");
            assert_eq!(bytes, ring_allreduce_wire_bytes(w, n, DType::F32));
        }
    }

    #[test]
    fn half_allreduce_replicas_agree_and_approximate_the_sum() {
        for wire in [DType::F16, DType::Bf16] {
            for (w, n) in [(2, 10), (4, 257), (8, 31), (5, 4099)] {
                let mut bufs = random_bufs(w, n, (w * 7 + n) as u64);
                let expect: Vec<f32> =
                    (0..n).map(|i| bufs.iter().map(|b| b[i]).sum()).collect();
                ring_allreduce_half(&mut bufs, wire);
                for b in &bufs[1..] {
                    assert_eq!(&bufs[0], b, "{} replicas disagree", wire.name());
                }
                // half wire: ~2^-11 (f16) / 2^-8 (bf16) relative per hop,
                // compounded over up to W-1 requantized partial sums
                let tol = if wire == DType::F16 { 0.1 } else { 0.5 };
                for (got, want) in bufs[0].iter().zip(&expect) {
                    assert!(
                        (got - want).abs() <= tol * want.abs().max(1.0),
                        "{}: {got} vs {want} (w={w} n={n})",
                        wire.name()
                    );
                }
            }
        }
    }

    #[test]
    fn pooled_half_matches_serial_bit_for_bit() {
        for wire in [DType::F16, DType::Bf16] {
            for (w, n, threads) in
                [(2, 10, 4), (8, 3, 4), (2, 5000, 4), (3, 4099, 2), (4, 30011, 8)]
            {
                let pool = ThreadPool::new(threads);
                let template = random_bufs(w, n, (w * 31 + n + threads) as u64);

                let mut serial = template.clone();
                let mut pooled = template.clone();
                let bs = ring_reduce_scatter_half(&mut serial, wire);
                let bp = ring_reduce_scatter_half_pooled(&mut pooled, wire, &pool);
                assert_eq!(serial, pooled, "{} rs w={w} n={n}", wire.name());
                assert_eq!(bs, bp);

                let bs = ring_all_gather_half(&mut serial, wire);
                let bp = ring_all_gather_half_pooled(&mut pooled, wire, &pool);
                assert_eq!(serial, pooled, "{} ag w={w} n={n}", wire.name());
                assert_eq!(bs, bp);
            }
        }
    }

    #[test]
    fn half_wire_moves_half_the_bytes() {
        for (w, n) in [(2, 100), (8, 4096), (192, 1 << 20)] {
            let f32b = ring_allreduce_wire_bytes(w, n, DType::F32);
            let f16b = ring_allreduce_wire_bytes(w, n, DType::F16);
            assert_eq!(f16b * 2, f32b, "w={w} n={n}");
            assert_eq!(ring_allreduce_wire_bytes(w, n, DType::Bf16), f16b);
        }
        assert_eq!(ring_allreduce_wire_bytes(1, 1000, DType::F16), 0);
    }

    #[test]
    fn executed_bytes_match_the_analytic_count() {
        let (w, n) = (4, 999);
        let mut bufs = random_bufs(w, n, 9);
        let rs = ring_reduce_scatter_half(&mut bufs, DType::F16);
        let ag = ring_all_gather_half(&mut bufs, DType::F16);
        assert_eq!(rs, ring_phase_wire_bytes(w, n, DType::F16));
        assert_eq!(rs + ag, ring_allreduce_wire_bytes(w, n, DType::F16));
    }

    #[test]
    fn gather_values_survive_requantization() {
        // the circulated values are exactly representable in the wire
        // format, so a second quantization is the identity
        let (w, n) = (4, 200);
        let mut bufs = random_bufs(w, n, 17);
        ring_allreduce_half(&mut bufs, DType::F16);
        for b in &bufs {
            for &x in b.iter() {
                assert_eq!(DType::F16.round_trip(x).to_bits(), x.to_bits());
            }
        }
    }

    #[test]
    fn width_one_ring_is_identity() {
        let mut bufs = vec![vec![0.1f32, 0.2, 0.3]];
        let orig = bufs.clone();
        let bytes = ring_allreduce_half(&mut bufs, DType::F16);
        assert_eq!(bufs, orig);
        assert_eq!(bytes, 0);
    }
}
