//! α-β cost model for collectives — prices the communication the trainer's
//! in-process ring actually performs, at the scale of the paper's testbeds
//! (192 × P3dn.24xlarge with EFA, or a TPUv3 pod).
//!
//! Ring allreduce over W endpoints of N bytes:
//!     T = 2(W−1)·α + 2·(W−1)/W · N / β
//! (latency term + the classic 2(W−1)/W bandwidth factor).
//!
//! Hierarchical (node-level) allreduce, the scheme real NCCL/EFA deployments
//! use: intra-node reduce over NVLink, inter-node ring over NIC, intra-node
//! broadcast:
//!     T = T_ring(gpus_per_node, NVLink) + T_ring(nodes, NIC) +
//!         T_bcast(gpus_per_node, NVLink)

/// One communication level: link latency (s) and per-endpoint bandwidth (B/s).
#[derive(Debug, Clone, Copy)]
pub struct CommSpec {
    pub alpha_s: f64,
    pub beta_bytes_per_s: f64,
}

impl CommSpec {
    /// NVLink within a P3dn node (~25 GB/s effective per direction per GPU
    /// for ring traffic on V100 NVLink2).
    pub fn nvlink() -> CommSpec {
        CommSpec { alpha_s: 3e-6, beta_bytes_per_s: 25e9 }
    }

    /// EFA on P3dn.24xlarge: 100 Gb/s per node ≈ 12.5 GB/s, ~15 µs latency.
    pub fn efa() -> CommSpec {
        CommSpec { alpha_s: 15e-6, beta_bytes_per_s: 12.5e9 }
    }

    /// TPUv3 ICI: ~70 GB/s per link, ~1 µs latency.
    pub fn tpu_ici() -> CommSpec {
        CommSpec { alpha_s: 1e-6, beta_bytes_per_s: 70e9 }
    }
}

/// Which collective schedule a modeled training step uses to combine
/// gradients and distribute the update (see `cluster::timemodel`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Collective {
    /// Classic data parallelism: allreduce the full gradient, every worker
    /// runs the full (replicated) optimizer update.
    AllReduce,
    /// ZeRO-1 style: reduce-scatter gradients, each worker updates only its
    /// owned shard, all-gather the updated parameters.
    ReduceScatterGather,
}

/// Flat ring allreduce time (seconds) for `bytes` across `w` endpoints.
pub fn allreduce_time_s(w: usize, bytes: f64, link: CommSpec) -> f64 {
    if w <= 1 {
        return 0.0;
    }
    let wf = w as f64;
    2.0 * (wf - 1.0) * link.alpha_s
        + 2.0 * (wf - 1.0) / wf * bytes / link.beta_bytes_per_s
}

/// Ring reduce-scatter time for `bytes` across `w` endpoints:
///     T = (W−1)·α + (W−1)/W · N / β
/// — exactly half the allreduce, which is its reduce-scatter + all-gather
/// composition.
pub fn reduce_scatter_time_s(w: usize, bytes: f64, link: CommSpec) -> f64 {
    if w <= 1 {
        return 0.0;
    }
    let wf = w as f64;
    (wf - 1.0) * link.alpha_s + (wf - 1.0) / wf * bytes / link.beta_bytes_per_s
}

/// Ring all-gather time; the same α-β shape as [`reduce_scatter_time_s`]
/// (each endpoint contributes its `N/W` shard and receives the rest).
pub fn all_gather_time_s(w: usize, bytes: f64, link: CommSpec) -> f64 {
    reduce_scatter_time_s(w, bytes, link)
}

/// Two-level reduce-scatter (`nodes` × `gpus_per_node`): intra-node
/// reduce-scatter over the full message, then an inter-node reduce-scatter
/// over each rank's `1/gpus_per_node` shard.
///
/// Baseline caveat: [`hierarchical_allreduce_time_s`] deliberately prices
/// its inter-node ring over the *full* message (a conservative, naive
/// schedule — the form it was calibrated against).  These shard-aware
/// halves move only the per-node shard inter-node, so part of the gap
/// between `ReduceScatterGather` and `AllReduce` in the time model
/// reflects that baseline pessimism: a shard-aware NCCL hierarchical
/// allreduce lands between the two.  The robust, schedule-independent win
/// of the sharded optimizer is the update term
/// (`ClusterSpec::optimizer_update_time_s`), not the wire time.
pub fn hierarchical_reduce_scatter_time_s(
    nodes: usize,
    gpus_per_node: usize,
    bytes: f64,
    intra: CommSpec,
    inter: CommSpec,
) -> f64 {
    reduce_scatter_time_s(gpus_per_node, bytes, intra)
        + reduce_scatter_time_s(nodes, bytes / gpus_per_node as f64, inter)
}

/// Two-level all-gather: the mirror of
/// [`hierarchical_reduce_scatter_time_s`] — inter-node gather of the
/// per-node shards, then intra-node gather of the full message.
pub fn hierarchical_all_gather_time_s(
    nodes: usize,
    gpus_per_node: usize,
    bytes: f64,
    intra: CommSpec,
    inter: CommSpec,
) -> f64 {
    all_gather_time_s(nodes, bytes / gpus_per_node as f64, inter)
        + all_gather_time_s(gpus_per_node, bytes, intra)
}

/// Broadcast (ring pipeline) time for `bytes` across `w` endpoints.
pub fn broadcast_time_s(w: usize, bytes: f64, link: CommSpec) -> f64 {
    if w <= 1 {
        return 0.0;
    }
    (w as f64 - 1.0) * link.alpha_s + bytes / link.beta_bytes_per_s
}

/// Two-level hierarchical allreduce: `nodes` × `gpus_per_node`.
pub fn hierarchical_allreduce_time_s(
    nodes: usize,
    gpus_per_node: usize,
    bytes: f64,
    intra: CommSpec,
    inter: CommSpec,
) -> f64 {
    // intra-node reduce-scatter+gather ≈ one intra allreduce
    let t_intra = allreduce_time_s(gpus_per_node, bytes, intra);
    // one endpoint per node participates in the inter-node ring
    let t_inter = allreduce_time_s(nodes, bytes, inter);
    let t_bcast = broadcast_time_s(gpus_per_node, bytes, intra);
    t_intra + t_inter + t_bcast
}

/// Naive single ring over every GPU: all `gpus_per_node` ranks of a node
/// share its NIC, so the effective per-endpoint inter-node bandwidth is
/// `inter.beta / gpus_per_node`.  This is the baseline hierarchical
/// allreduce improves on.
pub fn flat_gpu_ring_time_s(
    nodes: usize,
    gpus_per_node: usize,
    bytes: f64,
    inter: CommSpec,
) -> f64 {
    let shared = CommSpec {
        alpha_s: inter.alpha_s,
        beta_bytes_per_s: inter.beta_bytes_per_s / gpus_per_node as f64,
    };
    allreduce_time_s(nodes * gpus_per_node, bytes, shared)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_worker_free() {
        assert_eq!(allreduce_time_s(1, 1e9, CommSpec::efa()), 0.0);
    }

    #[test]
    fn bandwidth_term_dominates_large_messages() {
        // BERT-Large grads: ~340M params * 4B = 1.36 GB over 192 nodes
        let t = allreduce_time_s(192, 1.36e9, CommSpec::efa());
        // 2*(191/192)*1.36e9/12.5e9 ≈ 0.217 s; latency adds ~6 ms
        assert!(t > 0.20 && t < 0.25, "t = {t}");
    }

    #[test]
    fn scaling_with_workers_saturates() {
        let b = 1e9;
        let t64 = allreduce_time_s(64, b, CommSpec::efa());
        let t256 = allreduce_time_s(256, b, CommSpec::efa());
        // bandwidth term saturates at 2N/beta — within 2% between 64 and 256
        assert!((t256 - t64) / t64 < 0.05);
    }

    #[test]
    fn hierarchical_beats_flat_at_scale() {
        let bytes = 1.36e9;
        let flat = flat_gpu_ring_time_s(192, 8, bytes, CommSpec::efa());
        let hier = hierarchical_allreduce_time_s(
            192, 8, bytes, CommSpec::nvlink(), CommSpec::efa());
        assert!(hier < flat, "hier {hier} vs flat {flat}");
    }

    #[test]
    fn reduce_scatter_plus_all_gather_is_allreduce() {
        for w in [1, 2, 7, 192] {
            for bytes in [0.0, 4096.0, 1.36e9] {
                let rs = reduce_scatter_time_s(w, bytes, CommSpec::efa());
                let ag = all_gather_time_s(w, bytes, CommSpec::efa());
                let ar = allreduce_time_s(w, bytes, CommSpec::efa());
                assert!(
                    (rs + ag - ar).abs() <= 1e-12 * ar.max(1e-12),
                    "w={w} bytes={bytes}: {rs} + {ag} vs {ar}"
                );
            }
        }
    }

    #[test]
    fn single_endpoint_halves_are_free() {
        assert_eq!(reduce_scatter_time_s(1, 1e9, CommSpec::efa()), 0.0);
        assert_eq!(all_gather_time_s(1, 1e9, CommSpec::efa()), 0.0);
    }

    #[test]
    fn hierarchical_halves_cheaper_than_hierarchical_allreduce() {
        // the inter-node phases move 1/gpus_per_node of the bytes, so the
        // two halves together undercut the full-message hierarchical
        // allreduce at P3dn scale
        let bytes = 1.36e9;
        let (intra, inter) = (CommSpec::nvlink(), CommSpec::efa());
        let rs = hierarchical_reduce_scatter_time_s(192, 8, bytes, intra, inter);
        let ag = hierarchical_all_gather_time_s(192, 8, bytes, intra, inter);
        let ar = hierarchical_allreduce_time_s(192, 8, bytes, intra, inter);
        assert!(rs + ag < ar, "{rs} + {ag} vs {ar}");
    }
}
