//! α-β cost model for collectives — prices the communication the trainer's
//! in-process ring actually performs, at the scale of the paper's testbeds
//! (192 × P3dn.24xlarge with EFA, or a TPUv3 pod).
//!
//! Ring allreduce over W endpoints of N bytes:
//!     T = 2(W−1)·α + 2·(W−1)/W · N / β
//! (latency term + the classic 2(W−1)/W bandwidth factor).
//!
//! Hierarchical (node-level) allreduce, the scheme real NCCL/EFA deployments
//! use: intra-node reduce over NVLink, inter-node ring over NIC, intra-node
//! broadcast:
//!     T = T_ring(gpus_per_node, NVLink) + T_ring(nodes, NIC) +
//!         T_bcast(gpus_per_node, NVLink)

use crate::precision::DType;
use crate::topology::Topology;

use super::reduce_scatter::ring_chunk_starts;

/// One communication level: link latency (s) and per-endpoint bandwidth (B/s).
#[derive(Debug, Clone, Copy)]
pub struct CommSpec {
    pub alpha_s: f64,
    pub beta_bytes_per_s: f64,
}

impl CommSpec {
    /// NVLink within a P3dn node (~25 GB/s effective per direction per GPU
    /// for ring traffic on V100 NVLink2).
    pub fn nvlink() -> CommSpec {
        CommSpec { alpha_s: 3e-6, beta_bytes_per_s: 25e9 }
    }

    /// EFA on P3dn.24xlarge: 100 Gb/s per node ≈ 12.5 GB/s, ~15 µs latency.
    pub fn efa() -> CommSpec {
        CommSpec { alpha_s: 15e-6, beta_bytes_per_s: 12.5e9 }
    }

    /// TPUv3 ICI: ~70 GB/s per link, ~1 µs latency.
    pub fn tpu_ici() -> CommSpec {
        CommSpec { alpha_s: 1e-6, beta_bytes_per_s: 70e9 }
    }
}

/// Which collective schedule a modeled training step uses to combine
/// gradients and distribute the update (see `cluster::timemodel`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Collective {
    /// Classic data parallelism: allreduce the full gradient, every worker
    /// runs the full (replicated) optimizer update.
    AllReduce,
    /// ZeRO-1 style: reduce-scatter gradients, each worker updates only its
    /// owned shard, all-gather the updated parameters.
    ReduceScatterGather,
}

/// Flat ring allreduce time (seconds) for `bytes` across `w` endpoints.
pub fn allreduce_time_s(w: usize, bytes: f64, link: CommSpec) -> f64 {
    if w <= 1 {
        return 0.0;
    }
    let wf = w as f64;
    2.0 * (wf - 1.0) * link.alpha_s
        + 2.0 * (wf - 1.0) / wf * bytes / link.beta_bytes_per_s
}

/// Ring reduce-scatter time for `bytes` across `w` endpoints:
///     T = (W−1)·α + (W−1)/W · N / β
/// — exactly half the allreduce, which is its reduce-scatter + all-gather
/// composition.
pub fn reduce_scatter_time_s(w: usize, bytes: f64, link: CommSpec) -> f64 {
    if w <= 1 {
        return 0.0;
    }
    let wf = w as f64;
    (wf - 1.0) * link.alpha_s + (wf - 1.0) / wf * bytes / link.beta_bytes_per_s
}

/// Ring all-gather time; the same α-β shape as [`reduce_scatter_time_s`]
/// (each endpoint contributes its `N/W` shard and receives the rest).
pub fn all_gather_time_s(w: usize, bytes: f64, link: CommSpec) -> f64 {
    reduce_scatter_time_s(w, bytes, link)
}

/// Two-level reduce-scatter (`nodes` × `gpus_per_node`): intra-node
/// reduce-scatter over the full message, then an inter-node reduce-scatter
/// over each rank's `1/gpus_per_node` shard.
///
/// Baseline caveat: [`hierarchical_allreduce_time_s`] deliberately prices
/// its inter-node ring over the *full* message (a conservative, naive
/// schedule — the form it was calibrated against).  These shard-aware
/// halves move only the per-node shard inter-node, so part of the gap
/// between `ReduceScatterGather` and `AllReduce` in the time model
/// reflects that baseline pessimism:
/// [`hierarchical_allreduce_shard_aware_time_s`] is the shard-aware
/// allreduce that lands between the two.  The robust,
/// schedule-independent win of the sharded optimizer is the update term
/// (`ClusterSpec::optimizer_update_time_s`), not the wire time.
pub fn hierarchical_reduce_scatter_time_s(
    nodes: usize,
    gpus_per_node: usize,
    bytes: f64,
    intra: CommSpec,
    inter: CommSpec,
) -> f64 {
    hierarchical_reduce_scatter_time_tiered_s(nodes, gpus_per_node, bytes, bytes, intra, inter)
}

/// [`hierarchical_reduce_scatter_time_s`] at per-tier wire widths:
/// `intra_bytes` crosses the intra-node phase, `inter_bytes` sizes the
/// inter-node shard phase (mixed fp32-intra / f16-inter topologies halve
/// only the inter term).  Equal widths reproduce the single-width formula
/// exactly.
pub fn hierarchical_reduce_scatter_time_tiered_s(
    nodes: usize,
    gpus_per_node: usize,
    intra_bytes: f64,
    inter_bytes: f64,
    intra: CommSpec,
    inter: CommSpec,
) -> f64 {
    reduce_scatter_time_s(gpus_per_node, intra_bytes, intra)
        + reduce_scatter_time_s(nodes, inter_bytes / gpus_per_node as f64, inter)
}

/// Two-level all-gather: the mirror of
/// [`hierarchical_reduce_scatter_time_s`] — inter-node gather of the
/// per-node shards, then intra-node gather of the full message.
pub fn hierarchical_all_gather_time_s(
    nodes: usize,
    gpus_per_node: usize,
    bytes: f64,
    intra: CommSpec,
    inter: CommSpec,
) -> f64 {
    hierarchical_all_gather_time_tiered_s(nodes, gpus_per_node, bytes, bytes, intra, inter)
}

/// [`hierarchical_all_gather_time_s`] at per-tier wire widths; see
/// [`hierarchical_reduce_scatter_time_tiered_s`].
pub fn hierarchical_all_gather_time_tiered_s(
    nodes: usize,
    gpus_per_node: usize,
    intra_bytes: f64,
    inter_bytes: f64,
    intra: CommSpec,
    inter: CommSpec,
) -> f64 {
    all_gather_time_s(nodes, inter_bytes / gpus_per_node as f64, inter)
        + all_gather_time_s(gpus_per_node, intra_bytes, intra)
}

/// Broadcast (ring pipeline) time for `bytes` across `w` endpoints.
pub fn broadcast_time_s(w: usize, bytes: f64, link: CommSpec) -> f64 {
    if w <= 1 {
        return 0.0;
    }
    (w as f64 - 1.0) * link.alpha_s + bytes / link.beta_bytes_per_s
}

/// Two-level hierarchical allreduce: `nodes` × `gpus_per_node`.
pub fn hierarchical_allreduce_time_s(
    nodes: usize,
    gpus_per_node: usize,
    bytes: f64,
    intra: CommSpec,
    inter: CommSpec,
) -> f64 {
    hierarchical_allreduce_time_tiered_s(nodes, gpus_per_node, bytes, bytes, intra, inter)
}

/// [`hierarchical_allreduce_time_s`] at per-tier wire widths (the naive
/// full-message inter ring, priced at `inter_bytes`).
pub fn hierarchical_allreduce_time_tiered_s(
    nodes: usize,
    gpus_per_node: usize,
    intra_bytes: f64,
    inter_bytes: f64,
    intra: CommSpec,
    inter: CommSpec,
) -> f64 {
    // intra-node reduce-scatter+gather ≈ one intra allreduce
    let t_intra = allreduce_time_s(gpus_per_node, intra_bytes, intra);
    // one endpoint per node participates in the inter-node ring
    let t_inter = allreduce_time_s(nodes, inter_bytes, inter);
    let t_bcast = broadcast_time_s(gpus_per_node, intra_bytes, intra);
    t_intra + t_inter + t_bcast
}

/// Shard-aware two-level allreduce — the variant the baseline caveat on
/// [`hierarchical_reduce_scatter_time_s`] promises: the inter-node ring
/// runs over node leaders on the `1/gpus_per_node` reduced shard (the β
/// term divides by `gpus_per_node`) instead of the naive full message,
/// then the intra-node gather distributes the result.  Lands between
/// [`hierarchical_allreduce_time_s`] and the reduce-scatter/all-gather
/// composition, as the caveat describes.
pub fn hierarchical_allreduce_shard_aware_time_s(
    nodes: usize,
    gpus_per_node: usize,
    bytes: f64,
    intra: CommSpec,
    inter: CommSpec,
) -> f64 {
    reduce_scatter_time_s(gpus_per_node, bytes, intra)
        + allreduce_time_s(nodes, bytes / gpus_per_node as f64, inter)
        + all_gather_time_s(gpus_per_node, bytes, intra)
}

/// Naive single ring over every GPU: all `gpus_per_node` ranks of a node
/// share its NIC, so the effective per-endpoint inter-node bandwidth is
/// `inter.beta / gpus_per_node`.  This is the baseline hierarchical
/// allreduce improves on.
pub fn flat_gpu_ring_time_s(
    nodes: usize,
    gpus_per_node: usize,
    bytes: f64,
    inter: CommSpec,
) -> f64 {
    let shared = CommSpec {
        alpha_s: inter.alpha_s,
        beta_bytes_per_s: inter.beta_bytes_per_s / gpus_per_node as f64,
    };
    allreduce_time_s(nodes * gpus_per_node, bytes, shared)
}

/// Analytic wire bytes, split `(intra, inter)` and summed over all
/// endpoints, for one phase of the executed two-tier ring
/// (`collective::hierarchical`) over `elems` f32 elements.
///
/// Under the node-contiguous rank layout, chunk `c`'s `W−1`-hop path ends
/// at every rank except one — the chunk index itself in the reduce-scatter
/// phase, its owner `(c+W−1) % W` in the all-gather phase (`gather`
/// selects which).  A hop ending at rank `t` crosses a node boundary iff
/// `t % gpus_per_node == 0` (and there is more than one node), so each
/// chunk pays `nodes` inter-node crossings minus at most the one its path
/// skips.  For equal chunks the inter total per phase collapses to
/// `(W−1)·N·b / gpus_per_node` — exactly `1/gpus_per_node` of the
/// node-oblivious flat ring's `(W−1)·N·b`, the shrink the
/// `hierarchical_collectives` bench asserts.
pub fn tiered_ring_phase_wire_bytes(
    nodes: usize,
    gpus_per_node: usize,
    elems: usize,
    intra: DType,
    inter: DType,
    gather: bool,
) -> (u64, u64) {
    tiered_ring_phase_wire_bytes_range(nodes, gpus_per_node, elems, 0, elems, intra, inter, gather)
}

/// [`tiered_ring_phase_wire_bytes`] restricted to the element range
/// `[lo, hi)` of the global chunk grid (the grid is still built from
/// `elems`) — the analytic mirror of the executed range collectives
/// (`hierarchical_*_range`): each chunk contributes only its clipped
/// length.  Summing over any partition of `[0, elems)` reproduces the
/// full-phase counter exactly, which is the per-bucket wire-accounting
/// invariant the `overlap_step` bench asserts.
#[allow(clippy::too_many_arguments)]
pub fn tiered_ring_phase_wire_bytes_range(
    nodes: usize,
    gpus_per_node: usize,
    elems: usize,
    lo: usize,
    hi: usize,
    intra: DType,
    inter: DType,
    gather: bool,
) -> (u64, u64) {
    let w = nodes * gpus_per_node;
    assert!(lo <= hi && hi <= elems, "bad range {lo}..{hi} for elems={elems}");
    if w <= 1 {
        return (0, 0);
    }
    // one home for the node-boundary count: the same Topology helper the
    // executed collectives use, so counters and execution cannot drift
    let topo = Topology::grid(nodes, gpus_per_node);
    let starts = ring_chunk_starts(w, elems);
    let (mut intra_b, mut inter_b) = (0u64, 0u64);
    for c in 0..w {
        let (clo, chi) = (starts[c].max(lo), starts[c + 1].min(hi));
        if clo >= chi {
            continue;
        }
        let len = (chi - clo) as u64;
        let excl = if gather { (c + w - 1) % w } else { c };
        let inter_hops = topo.inter_hops_excluding(excl);
        let intra_hops = w - 1 - inter_hops;
        intra_b += len * intra_hops as u64 * intra.bytes() as u64;
        inter_b += len * inter_hops as u64 * inter.bytes() as u64;
    }
    (intra_b, inter_b)
}

/// Both phases of the tiered-ring allreduce:
/// reduce-scatter + all-gather [`tiered_ring_phase_wire_bytes`] terms.
pub fn tiered_ring_allreduce_wire_bytes(
    nodes: usize,
    gpus_per_node: usize,
    elems: usize,
    intra: DType,
    inter: DType,
) -> (u64, u64) {
    let rs = tiered_ring_phase_wire_bytes(nodes, gpus_per_node, elems, intra, inter, false);
    let ag = tiered_ring_phase_wire_bytes(nodes, gpus_per_node, elems, intra, inter, true);
    (rs.0 + ag.0, rs.1 + ag.1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_worker_free() {
        assert_eq!(allreduce_time_s(1, 1e9, CommSpec::efa()), 0.0);
    }

    #[test]
    fn bandwidth_term_dominates_large_messages() {
        // BERT-Large grads: ~340M params * 4B = 1.36 GB over 192 nodes
        let t = allreduce_time_s(192, 1.36e9, CommSpec::efa());
        // 2*(191/192)*1.36e9/12.5e9 ≈ 0.217 s; latency adds ~6 ms
        assert!(t > 0.20 && t < 0.25, "t = {t}");
    }

    #[test]
    fn scaling_with_workers_saturates() {
        let b = 1e9;
        let t64 = allreduce_time_s(64, b, CommSpec::efa());
        let t256 = allreduce_time_s(256, b, CommSpec::efa());
        // bandwidth term saturates at 2N/beta — within 2% between 64 and 256
        assert!((t256 - t64) / t64 < 0.05);
    }

    #[test]
    fn hierarchical_beats_flat_at_scale() {
        let bytes = 1.36e9;
        let flat = flat_gpu_ring_time_s(192, 8, bytes, CommSpec::efa());
        let hier = hierarchical_allreduce_time_s(
            192, 8, bytes, CommSpec::nvlink(), CommSpec::efa());
        assert!(hier < flat, "hier {hier} vs flat {flat}");
    }

    #[test]
    fn reduce_scatter_plus_all_gather_is_allreduce() {
        for w in [1, 2, 7, 192] {
            for bytes in [0.0, 4096.0, 1.36e9] {
                let rs = reduce_scatter_time_s(w, bytes, CommSpec::efa());
                let ag = all_gather_time_s(w, bytes, CommSpec::efa());
                let ar = allreduce_time_s(w, bytes, CommSpec::efa());
                assert!(
                    (rs + ag - ar).abs() <= 1e-12 * ar.max(1e-12),
                    "w={w} bytes={bytes}: {rs} + {ag} vs {ar}"
                );
            }
        }
    }

    #[test]
    fn single_endpoint_halves_are_free() {
        assert_eq!(reduce_scatter_time_s(1, 1e9, CommSpec::efa()), 0.0);
        assert_eq!(all_gather_time_s(1, 1e9, CommSpec::efa()), 0.0);
    }

    #[test]
    fn shard_aware_allreduce_lands_between_naive_and_halves() {
        // the variant the baseline caveat promises: cheaper than the naive
        // full-message inter ring, dearer than the reduce-scatter +
        // all-gather composition whose inter phases move only shards
        let bytes = 1.36e9;
        let (intra, inter) = (CommSpec::nvlink(), CommSpec::efa());
        for (nodes, gpus) in [(192usize, 8usize), (24, 8), (4, 4)] {
            let naive = hierarchical_allreduce_time_s(nodes, gpus, bytes, intra, inter);
            let aware =
                hierarchical_allreduce_shard_aware_time_s(nodes, gpus, bytes, intra, inter);
            let halves = hierarchical_reduce_scatter_time_s(nodes, gpus, bytes, intra, inter)
                + hierarchical_all_gather_time_s(nodes, gpus, bytes, intra, inter);
            assert!(aware < naive, "{nodes}x{gpus}: {aware} !< {naive}");
            assert!(halves < aware, "{nodes}x{gpus}: {halves} !< {aware}");
        }
    }

    #[test]
    fn tiered_time_equals_single_width_at_equal_bytes() {
        // regression pin for the per-tier generalization: equal widths
        // reproduce the historical single-width formulas exactly
        let (intra, inter) = (CommSpec::nvlink(), CommSpec::efa());
        for bytes in [1.36e9, 6.8e8, 0.0] {
            for (nodes, gpus) in [(192usize, 8usize), (2, 4)] {
                assert_eq!(
                    hierarchical_allreduce_time_s(nodes, gpus, bytes, intra, inter),
                    hierarchical_allreduce_time_tiered_s(
                        nodes, gpus, bytes, bytes, intra, inter
                    )
                );
                assert_eq!(
                    hierarchical_reduce_scatter_time_s(nodes, gpus, bytes, intra, inter),
                    hierarchical_reduce_scatter_time_tiered_s(
                        nodes, gpus, bytes, bytes, intra, inter
                    )
                );
                assert_eq!(
                    hierarchical_all_gather_time_s(nodes, gpus, bytes, intra, inter),
                    hierarchical_all_gather_time_tiered_s(
                        nodes, gpus, bytes, bytes, intra, inter
                    )
                );
            }
        }
        // a mixed fp32-intra / fp16-inter wire sits strictly between the
        // all-fp16 and all-fp32 prices
        let (b32, b16) = (1.36e9, 0.68e9);
        let hi = hierarchical_allreduce_time_tiered_s(192, 8, b32, b32, intra, inter);
        let lo = hierarchical_allreduce_time_tiered_s(192, 8, b16, b16, intra, inter);
        let mixed = hierarchical_allreduce_time_tiered_s(192, 8, b32, b16, intra, inter);
        assert!(lo < mixed && mixed < hi, "{lo} < {mixed} < {hi}");
    }

    #[test]
    fn tiered_ring_bytes_shrink_inter_by_gpus_per_node() {
        // exact identity at equal chunks: the tiered ring's inter bytes are
        // 1/gpus_per_node of the node-oblivious flat ring's, per phase
        for (nodes, gpus, n) in [(2usize, 2usize, 4096usize), (2, 4, 65536), (4, 8, 1 << 15)] {
            let w = nodes * gpus;
            assert_eq!(n % w, 0, "test wants equal chunks");
            for gather in [false, true] {
                let (intra, inter) = tiered_ring_phase_wire_bytes(
                    nodes, gpus, n, DType::F32, DType::F32, gather,
                );
                let flat = tiered_ring_phase_wire_bytes(w, 1, n, DType::F32, DType::F32, gather);
                assert_eq!(flat.0, 0, "flat has no intra tier");
                assert_eq!(flat.1, (w as u64 - 1) * n as u64 * 4);
                assert_eq!(inter * gpus as u64, flat.1, "{nodes}x{gpus} gather={gather}");
                // total volume is conserved — only which tier carries it moves
                assert_eq!(intra + inter, flat.1);
            }
        }
        // degenerate cases are free / single-tier
        assert_eq!(tiered_ring_phase_wire_bytes(1, 1, 999, DType::F32, DType::F32, false), (0, 0));
        let one_node = tiered_ring_phase_wire_bytes(1, 6, 600, DType::F32, DType::F32, false);
        assert_eq!(one_node.1, 0, "single node never crosses a NIC");
        assert_eq!(one_node.0, 5 * 600 * 4);
    }

    #[test]
    fn shard_aware_pricing_cross_checks_executed_byte_counts() {
        // the shard-aware inter β term prices (nodes−1)/nodes · N/G bytes
        // per NIC (node leaders ring the reduced shard); the executed
        // tiered ring keeps one W-rank ring, so each NIC carries the full
        // (W−1)/W · N — exactly (W−1)/(nodes−1) ≈ G more.  The leader
        // schedule is therefore a strict lower bound on the executed
        // count, and the gap factor is pinned here so the pricing and the
        // byte counters cannot drift apart silently.
        let n = 393_216; // 3 · 2^17 elems — divisible by every W below (8, 64, 1536)
        for (nodes, gpus) in [(2usize, 4usize), (8, 8), (192, 8)] {
            let w = nodes * gpus;
            let (_, inter_total) =
                tiered_ring_phase_wire_bytes(nodes, gpus, n, DType::F32, DType::F32, false);
            let executed_per_nic = inter_total as f64 / nodes as f64;
            let model_per_nic =
                (nodes as f64 - 1.0) / nodes as f64 * (n as f64 * 4.0) / gpus as f64;
            assert!(
                model_per_nic <= executed_per_nic,
                "{nodes}x{gpus}: model {model_per_nic} > executed {executed_per_nic}"
            );
            let ratio = executed_per_nic / model_per_nic;
            let expect = (w as f64 - 1.0) / (nodes as f64 - 1.0);
            assert!((ratio - expect).abs() < 1e-9, "{nodes}x{gpus}: {ratio} vs {expect}");
            // the gap never exceeds the G-fold fan-in the leader skips
            // (W−1)/(nodes−1) ≤ G·(nodes)/(nodes−1), and → G at scale
            if nodes >= 192 {
                assert!(
                    (ratio - gpus as f64).abs() / gpus as f64 < 0.01,
                    "at paper scale the gap is the fan-in factor: {ratio} vs {gpus}"
                );
            }
        }
    }

    #[test]
    fn range_wire_bytes_partition_to_the_full_counter() {
        // per-bucket analytic bytes over any partition of [0, elems) must
        // sum exactly to the full-phase counter, for every tier dtype mix
        for (nodes, gpus, n) in [(1usize, 4usize, 30011usize), (2, 4, 4099), (4, 2, 65536)] {
            for (intra, inter) in
                [(DType::F32, DType::F32), (DType::F32, DType::Bf16), (DType::F16, DType::F16)]
            {
                for gather in [false, true] {
                    let full =
                        tiered_ring_phase_wire_bytes(nodes, gpus, n, intra, inter, gather);
                    for cuts in [vec![0, n], vec![0, 1, n / 2, n], vec![0, 4096, 4096, n]] {
                        let mut acc = (0u64, 0u64);
                        for b in cuts.windows(2) {
                            let (i, x) = tiered_ring_phase_wire_bytes_range(
                                nodes, gpus, n, b[0], b[1], intra, inter, gather,
                            );
                            acc.0 += i;
                            acc.1 += x;
                        }
                        assert_eq!(acc, full, "{nodes}x{gpus} n={n} cuts={cuts:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn hierarchical_halves_cheaper_than_hierarchical_allreduce() {
        // the inter-node phases move 1/gpus_per_node of the bytes, so the
        // two halves together undercut the full-message hierarchical
        // allreduce at P3dn scale
        let bytes = 1.36e9;
        let (intra, inter) = (CommSpec::nvlink(), CommSpec::efa());
        let rs = hierarchical_reduce_scatter_time_s(192, 8, bytes, intra, inter);
        let ag = hierarchical_all_gather_time_s(192, 8, bytes, intra, inter);
        let ar = hierarchical_allreduce_time_s(192, 8, bytes, intra, inter);
        assert!(rs + ag < ar, "{rs} + {ag} vs {ar}");
    }
}
