//! Collectives: a real (summing) ring allreduce over in-process gradient
//! buffers, plus the α-β cost model used by the cluster time simulator.

pub mod cost;
pub mod ring;

pub use cost::{allreduce_time_s, CommSpec};
pub use ring::{ring_allreduce, ring_allreduce_avg, ring_allreduce_pooled};
