//! Collectives: a real (summing) ring allreduce over in-process gradient
//! buffers, the reduce-scatter / all-gather halves it is composed from
//! (the sharded-optimizer path uses them directly), the half-precision
//! wire variants of both (fp16/bf16 chunks on the wire, f32
//! accumulation), the topology-aware two-tier variants (per-tier wire
//! precision over a declared `nodes × gpus_per_node` fabric, split
//! intra/inter byte accounting), plus the α-β cost model used by the
//! cluster time simulator.

pub mod cost;
pub mod half;
pub mod hierarchical;
pub mod reduce_scatter;
pub mod ring;

pub use cost::{
    allreduce_time_s, tiered_ring_allreduce_wire_bytes, tiered_ring_phase_wire_bytes,
    tiered_ring_phase_wire_bytes_range, Collective, CommSpec,
};
pub use hierarchical::{
    hierarchical_all_gather, hierarchical_all_gather_pooled, hierarchical_all_gather_range,
    hierarchical_all_gather_views, hierarchical_allreduce, hierarchical_allreduce_pooled,
    hierarchical_allreduce_range, hierarchical_allreduce_wire_bytes,
    hierarchical_phase_wire_bytes, hierarchical_phase_wire_bytes_range,
    hierarchical_reduce_scatter, hierarchical_reduce_scatter_pooled,
    hierarchical_reduce_scatter_range, hierarchical_reduce_scatter_views, leader_allreduce,
    leader_allreduce_wire_bytes,
};
pub use half::{
    ring_all_gather_half, ring_all_gather_half_pooled, ring_allreduce_half,
    ring_allreduce_half_pooled, ring_allreduce_wire_bytes, ring_phase_wire_bytes,
    ring_reduce_scatter_half, ring_reduce_scatter_half_pooled,
};
pub use reduce_scatter::{
    chunk_owner, ring_all_gather, ring_all_gather_pooled, ring_all_gather_range,
    ring_chunk_starts, ring_reduce_scatter, ring_reduce_scatter_pooled,
    ring_reduce_scatter_range,
};
pub use ring::{ring_allreduce, ring_allreduce_avg, ring_allreduce_pooled};
