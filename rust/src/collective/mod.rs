//! Collectives: a real (summing) ring allreduce over in-process gradient
//! buffers, the reduce-scatter / all-gather halves it is composed from
//! (the sharded-optimizer path uses them directly), plus the α-β cost
//! model used by the cluster time simulator.

pub mod cost;
pub mod reduce_scatter;
pub mod ring;

pub use cost::{allreduce_time_s, Collective, CommSpec};
pub use reduce_scatter::{
    chunk_owner, ring_all_gather, ring_all_gather_pooled, ring_chunk_starts,
    ring_reduce_scatter, ring_reduce_scatter_pooled,
};
pub use ring::{ring_allreduce, ring_allreduce_avg, ring_allreduce_pooled};
