//! Executed two-level (topology-aware) ring collectives: the schedule the
//! paper's 192-node deployment actually runs — one ring laid out
//! node-contiguously so that of the `W` links in the cycle only `nodes`
//! cross a NIC, with a per-tier wire format (fp32 over NVLink, f16/bf16 on
//! the scarce inter-node hops).
//!
//! **Why a tiered ring and not a leader-based two-phase reduction.**  The
//! repo's bit-identity contract (DESIGN.md §8) pins the *per-element f32
//! reduction order*: the sharded optimizer stitches reduce-scattered
//! chunks assuming exactly [`ring_allreduce`]'s summation order, and the
//! replicated / parallel / sharded trajectories are exact-bit equal only
//! because every path folds in that order.  A leader-based hierarchical
//! reduction (pre-sum each node, ring the node sums) regroups the f32
//! adds — `(a+b)+(c+d)` instead of `((a+b)+c)+d` — and can never be
//! bitwise-equal to the flat ring.  The tiered ring keeps the flat
//! schedule's arithmetic *unchanged* (fp32 tiers are exact-bit equal to
//! [`ring_allreduce`] for every topology, by construction) and moves the
//! hierarchy into the *hops*: intra-node hops stay inside a node, and each
//! chunk crosses each NIC once per cycle instead of every hop — the
//! inter-node byte total shrinks by exactly `gpus_per_node` versus the
//! node-oblivious flat ring (`cost::tiered_ring_phase_wire_bytes`).  The
//! leader-based schedule survives in the cost model
//! (`cost::hierarchical_allreduce_shard_aware_time_s`) as the pricing
//! lower bound.
//!
//! Wire-precision semantics extend `collective::half` per tier:
//!
//! * **reduce-scatter** — a hop whose tier has a half wire format sends
//!   its chunk as packed half data and the receiver accumulates in f32
//!   (in process: one fused [`quantize_accumulate`] kernel per hop);
//!   fp32-tier hops add exactly.  Deterministic, so serial == pooled
//!   bit-for-bit, and the postcondition matches [`ring_reduce_scatter`]:
//!   chunk `c`'s sum sits at `chunk_owner(c, w)` — the sharded optimizer's
//!   `step_scattered` consumes the buffers unchanged.
//! * **all-gather** — each owner *adopts* the image of its chunk under
//!   every half format its gather path will cross (inter first, then
//!   intra), then the pure-copy ring circulates it; `q∘dq∘q = q` makes
//!   every later crossing the identity, so all replicas end bit-identical.
//!   [`TierPrecision::validate`] restricts tier combinations to ones where
//!   that fixed point exists (at most one distinct half format).
//!
//! Every entry point returns its executed wire bytes split by tier
//! ([`WireBytes`]), counted hop by hop where a wire loop runs; unit tests
//! and the `hierarchical_collectives` bench assert they equal the analytic
//! `cost.rs` terms.

use crate::precision::{quantize_accumulate, round_trip_slice, DType};
use crate::topology::{TierPrecision, Topology, WireBytes};
use crate::trace;
use crate::util::pool::ThreadPool;

use super::cost::{tiered_ring_phase_wire_bytes, tiered_ring_phase_wire_bytes_range};
use super::reduce_scatter::{
    check_bufs, chunk_owner, ring_all_gather, ring_all_gather_at, ring_all_gather_pooled,
    ring_chunk_starts, ring_reduce_scatter, ring_reduce_scatter_pooled, ring_step_tasks,
    split_two, POOLED_MIN_ELEMS,
};
#[cfg(doc)]
use super::ring::ring_allreduce;

/// Analytic wire bytes of one tiered-ring phase, as a [`WireBytes`] split
/// (`gather` selects the all-gather path variant — see
/// [`tiered_ring_phase_wire_bytes`]).
pub fn hierarchical_phase_wire_bytes(
    topo: &Topology,
    elems: usize,
    prec: TierPrecision,
    gather: bool,
) -> WireBytes {
    let (intra, inter) = tiered_ring_phase_wire_bytes(
        topo.nodes,
        topo.gpus_per_node,
        elems,
        prec.intra,
        prec.inter,
        gather,
    );
    WireBytes { intra, inter }
}

/// Analytic wire bytes of the full tiered allreduce (both phases).
pub fn hierarchical_allreduce_wire_bytes(
    topo: &Topology,
    elems: usize,
    prec: TierPrecision,
) -> WireBytes {
    hierarchical_phase_wire_bytes(topo, elems, prec, false)
        + hierarchical_phase_wire_bytes(topo, elems, prec, true)
}

/// [`hierarchical_phase_wire_bytes`] restricted to the element range
/// `[lo, hi)` of the global chunk grid — per-bucket sums over a partition
/// of `[0, elems)` equal the full-phase counter exactly.
pub fn hierarchical_phase_wire_bytes_range(
    topo: &Topology,
    elems: usize,
    lo: usize,
    hi: usize,
    prec: TierPrecision,
    gather: bool,
) -> WireBytes {
    let (intra, inter) = tiered_ring_phase_wire_bytes_range(
        topo.nodes,
        topo.gpus_per_node,
        elems,
        lo,
        hi,
        prec.intra,
        prec.inter,
        gather,
    );
    WireBytes { intra, inter }
}

fn check_topology(topo: &Topology, prec: TierPrecision, w: usize) {
    assert_eq!(topo.world(), w, "topology {topo} does not describe {w} buffers");
    if let Err(e) = prec.validate() {
        panic!("unsupported tier precision: {e}");
    }
}

/// Tiered-ring reduce-scatter.  Postcondition matches
/// [`ring_reduce_scatter`] (chunk `c`'s f32 sum at its `chunk_owner`);
/// with both tiers fp32 it *is* that function, bit for bit.  Returns the
/// executed wire bytes split by tier.
pub fn hierarchical_reduce_scatter(
    bufs: &mut [Vec<f32>],
    topo: &Topology,
    prec: TierPrecision,
) -> WireBytes {
    let mut sp = trace::span(trace::CAT_COMM, "hier_reduce_scatter");
    let wire = hierarchical_reduce_scatter_inner(bufs, topo, prec);
    sp.set_detail(wire.total());
    record_wire_metrics(&wire);
    wire
}

/// Metrics seam: executed wire bytes by tier, one `collective.calls` tick
/// per tiered primitive.  Lives only in the public wrappers that directly
/// wrap an `_inner` (plus [`leader_allreduce`]) — compositions such as
/// `hierarchical_allreduce` and the `_range` variants call those wrappers
/// and therefore count once per primitive they execute, never double.
fn record_wire_metrics(wire: &WireBytes) {
    use crate::metrics::registry;
    if registry::enabled() {
        registry::COLLECTIVE_CALLS.add(1);
        registry::WIRE_INTRA_BYTES.add(wire.intra);
        registry::WIRE_INTER_BYTES.add(wire.inter);
    }
}

fn hierarchical_reduce_scatter_inner(
    bufs: &mut [Vec<f32>],
    topo: &Topology,
    prec: TierPrecision,
) -> WireBytes {
    let (w, n) = check_bufs(bufs);
    check_topology(topo, prec, w);
    if !prec.any_half() {
        // the exact flat schedule — the tiers only relabel whose link
        // each hop uses, which the analytic counter accounts
        ring_reduce_scatter(bufs);
        return hierarchical_phase_wire_bytes(topo, n, prec, false);
    }
    let mut wire = WireBytes::default();
    if w == 1 || n == 0 {
        return wire;
    }
    let starts = ring_chunk_starts(w, n);
    for s in 0..w - 1 {
        for c in 0..w {
            let src = (c + s) % w;
            let dst = (c + s + 1) % w;
            let (lo, hi) = (starts[c], starts[c + 1]);
            if lo == hi {
                continue;
            }
            let tier = topo.ring_hop_tier(dst);
            let dtype = prec.tier(tier);
            wire.add(tier, ((hi - lo) * dtype.bytes()) as u64);
            let (a, b) = split_two(bufs, src, dst);
            if dtype.is_half() {
                // wire boundary: pack at the hop's tier format, widen and
                // accumulate in f32 at the receiver
                quantize_accumulate(dtype, &a[lo..hi], &mut b[lo..hi]);
            } else {
                for i in lo..hi {
                    b[i] += a[i];
                }
            }
        }
    }
    wire
}

/// Tiered-ring reduce-scatter restricted to the element range `[lo, hi)`
/// of the *global* chunk grid — the bucket-granular entry point of the
/// overlapped step.  The full `w−1`-step schedule runs with every chunk
/// (and its wire quantization) clipped to the range, so each in-range
/// element sees exactly the hops, formats and f32 accumulation order it
/// would under [`hierarchical_reduce_scatter`]: running this once per
/// bucket over a partition of `[0, n)` is bitwise identical to one
/// full-vector call, and the per-bucket [`WireBytes`] sum to the full
/// counter exactly ([`hierarchical_phase_wire_bytes_range`]).
pub fn hierarchical_reduce_scatter_range(
    bufs: &mut [Vec<f32>],
    topo: &Topology,
    prec: TierPrecision,
    lo: usize,
    hi: usize,
) -> WireBytes {
    let (_, n) = check_bufs(bufs);
    assert!(lo <= hi && hi <= n, "bad range {lo}..{hi} for n={n}");
    let mut views: Vec<&mut [f32]> = bufs.iter_mut().map(|b| &mut b[lo..hi]).collect();
    hierarchical_reduce_scatter_views(&mut views, n, lo, topo, prec)
}

/// [`hierarchical_reduce_scatter_range`] on pre-carved per-worker bucket
/// views — the entry point the DAG-scheduled step uses so communication
/// of one bucket can run while compute touches another without aliasing.
/// `views[i]` is worker `i`'s slice of the global element range
/// `[lo, lo + views[i].len())` of a buffer of `n` elements.  Same clipped
/// full-ring schedule, hop order, f32 accumulation and per-hop wire
/// accounting as the range/full entry points (which delegate here for the
/// range case); executed bytes equal the analytic
/// [`hierarchical_phase_wire_bytes_range`].
pub fn hierarchical_reduce_scatter_views(
    views: &mut [&mut [f32]],
    n: usize,
    lo: usize,
    topo: &Topology,
    prec: TierPrecision,
) -> WireBytes {
    let mut sp = trace::span(trace::CAT_COMM, "hier_reduce_scatter_views");
    let wire = hierarchical_reduce_scatter_views_inner(views, n, lo, topo, prec);
    sp.set_detail(wire.total());
    record_wire_metrics(&wire);
    wire
}

fn hierarchical_reduce_scatter_views_inner(
    views: &mut [&mut [f32]],
    n: usize,
    lo: usize,
    topo: &Topology,
    prec: TierPrecision,
) -> WireBytes {
    let w = views.len();
    assert!(w > 0, "no workers");
    let len = views[0].len();
    assert!(views.iter().all(|v| v.len() == len), "view length mismatch");
    let hi = lo + len;
    assert!(hi <= n, "bad view range {lo}..{hi} for n={n}");
    check_topology(topo, prec, w);
    let mut wire = WireBytes::default();
    if w == 1 || lo == hi {
        return wire;
    }
    let starts = ring_chunk_starts(w, n);
    for s in 0..w - 1 {
        for c in 0..w {
            let (clo, chi) = (starts[c].max(lo), starts[c + 1].min(hi));
            if clo >= chi {
                continue;
            }
            let src = (c + s) % w;
            let dst = (c + s + 1) % w;
            let tier = topo.ring_hop_tier(dst);
            let dtype = prec.tier(tier);
            wire.add(tier, ((chi - clo) * dtype.bytes()) as u64);
            let (a, b) = split_two(views, src, dst);
            let (vlo, vhi) = (clo - lo, chi - lo);
            if dtype.is_half() {
                quantize_accumulate(dtype, &a[vlo..vhi], &mut b[vlo..vhi]);
            } else {
                for i in vlo..vhi {
                    b[i] += a[i];
                }
            }
        }
    }
    wire
}

/// One pooled unit of a tiered ring step: the chunk task plus the wire
/// format of the hop it executes.
struct TieredTask<'a> {
    task: super::reduce_scatter::ChunkTask<'a>,
    dtype: DType,
}

/// Chunk-parallel [`hierarchical_reduce_scatter`]; bit-identical to the
/// serial path (falls back to it for width-1 pools / small buffers).
pub fn hierarchical_reduce_scatter_pooled(
    bufs: &mut [Vec<f32>],
    topo: &Topology,
    prec: TierPrecision,
    pool: &ThreadPool,
) -> WireBytes {
    let mut sp = trace::span(trace::CAT_COMM, "hier_reduce_scatter_pooled");
    let wire = hierarchical_reduce_scatter_pooled_inner(bufs, topo, prec, pool);
    sp.set_detail(wire.total());
    record_wire_metrics(&wire);
    wire
}

fn hierarchical_reduce_scatter_pooled_inner(
    bufs: &mut [Vec<f32>],
    topo: &Topology,
    prec: TierPrecision,
    pool: &ThreadPool,
) -> WireBytes {
    let (w, n) = check_bufs(bufs);
    check_topology(topo, prec, w);
    if !prec.any_half() {
        ring_reduce_scatter_pooled(bufs, pool);
        return hierarchical_phase_wire_bytes(topo, n, prec, false);
    }
    if pool.threads() <= 1 || w < 2 || n < POOLED_MIN_ELEMS {
        // `_inner`, not the public wrapper: the pooled wrapper already
        // recorded this call's trace span and will record its wire metrics
        return hierarchical_reduce_scatter_inner(bufs, topo, prec);
    }
    let starts = ring_chunk_starts(w, n);
    let mut wire = WireBytes::default();
    for s in 0..w - 1 {
        // per chunk c this step hops (c+s) → (c+s+1): resolve each hop's
        // tier before the region so the workers only quantize/accumulate
        let dtypes: Vec<DType> = (0..w)
            .map(|c| {
                let dst = (c + s + 1) % w;
                let tier = topo.ring_hop_tier(dst);
                wire.add(tier, ((starts[c + 1] - starts[c]) * prec.tier(tier).bytes()) as u64);
                prec.tier(tier)
            })
            .collect();
        let mut tasks: Vec<TieredTask<'_>> = ring_step_tasks(bufs, &starts, s, true)
            .into_iter()
            .zip(dtypes)
            .map(|(task, dtype)| TieredTask { task, dtype })
            .collect();
        pool.map_mut(&mut tasks, |t| {
            if t.dtype.is_half() {
                quantize_accumulate(t.dtype, t.task.src, t.task.dst);
            } else {
                for (d, x) in t.task.dst.iter_mut().zip(t.task.src.iter()) {
                    *d += *x;
                }
            }
        });
    }
    wire
}

/// The half formats chunk `c`'s gather path crosses, in adoption order
/// (inter first — with the supported tier combinations at most one
/// distinct format survives).  The path hops into every rank except the
/// chunk's owner, so it misses at most one inter link.
fn owner_roundings(
    topo: &Topology,
    prec: TierPrecision,
    c: usize,
) -> (Option<DType>, Option<DType>) {
    let w = topo.world();
    let owner = chunk_owner(c, w);
    let inter_hops = topo.inter_hops_excluding(owner);
    let intra_hops = (w - 1) - inter_hops;
    let first = (prec.inter.is_half() && inter_hops > 0).then_some(prec.inter);
    let second = (prec.intra.is_half() && intra_hops > 0 && first != Some(prec.intra))
        .then_some(prec.intra);
    (first, second)
}

/// Quantize a segment through `dtype` and adopt the dequantized image —
/// the owner-side half of the gather's wire boundary.
fn round_segment(seg: &mut [f32], dtype: DType) {
    round_trip_slice(dtype, seg);
}

/// Tiered-ring all-gather: assumes the [`hierarchical_reduce_scatter`]
/// postcondition, circulates every owner chunk until all buffers agree.
/// Replicas end bit-identical for every supported tier precision; with
/// both tiers fp32 it is [`ring_all_gather`] exactly.  Returns the
/// executed wire bytes split by tier.
pub fn hierarchical_all_gather(
    bufs: &mut [Vec<f32>],
    topo: &Topology,
    prec: TierPrecision,
) -> WireBytes {
    let mut sp = trace::span(trace::CAT_COMM, "hier_all_gather");
    let wire = hierarchical_all_gather_inner(bufs, topo, prec);
    sp.set_detail(wire.total());
    record_wire_metrics(&wire);
    wire
}

fn hierarchical_all_gather_inner(
    bufs: &mut [Vec<f32>],
    topo: &Topology,
    prec: TierPrecision,
) -> WireBytes {
    let (w, n) = check_bufs(bufs);
    check_topology(topo, prec, w);
    let bytes = hierarchical_phase_wire_bytes(topo, n, prec, true);
    if !prec.any_half() {
        ring_all_gather(bufs);
        return bytes;
    }
    if w == 1 || n == 0 {
        return bytes;
    }
    let starts = ring_chunk_starts(w, n);
    for c in 0..w {
        let (first, second) = owner_roundings(topo, prec, c);
        let o = chunk_owner(c, w);
        let seg = &mut bufs[o][starts[c]..starts[c + 1]];
        if let Some(d) = first {
            round_segment(seg, d);
        }
        if let Some(d) = second {
            round_segment(seg, d);
        }
    }
    // the circulation itself is pure copies of the adopted values — every
    // later wire crossing re-quantizes a fixed point (q∘dq∘q = q)
    ring_all_gather_at(bufs, &starts);
    bytes
}

/// Tiered-ring all-gather restricted to `[lo, hi)` of the global chunk
/// grid: each owner adopts the wire image of its *clipped* chunk (the
/// rounding is element-wise, so per-bucket adoption equals the full
/// call's), then the clipped pure-copy schedule circulates it.  Bucketing
/// over a partition of `[0, n)` reproduces
/// [`hierarchical_all_gather`] bitwise.
pub fn hierarchical_all_gather_range(
    bufs: &mut [Vec<f32>],
    topo: &Topology,
    prec: TierPrecision,
    lo: usize,
    hi: usize,
) -> WireBytes {
    let (_, n) = check_bufs(bufs);
    assert!(lo <= hi && hi <= n, "bad range {lo}..{hi} for n={n}");
    let mut views: Vec<&mut [f32]> = bufs.iter_mut().map(|b| &mut b[lo..hi]).collect();
    hierarchical_all_gather_views(&mut views, n, lo, topo, prec)
}

/// [`hierarchical_all_gather_range`] on pre-carved per-worker bucket views
/// (see [`hierarchical_reduce_scatter_views`]): each owner adopts the wire
/// image of its clipped chunk, then the clipped pure-copy schedule
/// circulates it.
pub fn hierarchical_all_gather_views(
    views: &mut [&mut [f32]],
    n: usize,
    lo: usize,
    topo: &Topology,
    prec: TierPrecision,
) -> WireBytes {
    let mut sp = trace::span(trace::CAT_COMM, "hier_all_gather_views");
    let wire = hierarchical_all_gather_views_inner(views, n, lo, topo, prec);
    sp.set_detail(wire.total());
    record_wire_metrics(&wire);
    wire
}

fn hierarchical_all_gather_views_inner(
    views: &mut [&mut [f32]],
    n: usize,
    lo: usize,
    topo: &Topology,
    prec: TierPrecision,
) -> WireBytes {
    let w = views.len();
    assert!(w > 0, "no workers");
    let len = views[0].len();
    assert!(views.iter().all(|v| v.len() == len), "view length mismatch");
    let hi = lo + len;
    assert!(hi <= n, "bad view range {lo}..{hi} for n={n}");
    check_topology(topo, prec, w);
    let bytes = hierarchical_phase_wire_bytes_range(topo, n, lo, hi, prec, true);
    if w == 1 || lo == hi {
        return bytes;
    }
    let starts = ring_chunk_starts(w, n);
    if prec.any_half() {
        for c in 0..w {
            let (clo, chi) = (starts[c].max(lo), starts[c + 1].min(hi));
            if clo >= chi {
                continue;
            }
            let (first, second) = owner_roundings(topo, prec, c);
            let o = chunk_owner(c, w);
            let seg = &mut views[o][clo - lo..chi - lo];
            if let Some(d) = first {
                round_segment(seg, d);
            }
            if let Some(d) = second {
                round_segment(seg, d);
            }
        }
    }
    for s in 0..w - 1 {
        for c in 0..w {
            let (clo, chi) = (starts[c].max(lo), starts[c + 1].min(hi));
            if clo >= chi {
                continue;
            }
            let src = (c + w - 1 + s) % w;
            let dst = (c + w + s) % w;
            let (a, b) = split_two(views, src, dst);
            b[clo - lo..chi - lo].copy_from_slice(&a[clo - lo..chi - lo]);
        }
    }
    bytes
}

/// Bucket-granular tiered allreduce:
/// [`hierarchical_reduce_scatter_range`] then
/// [`hierarchical_all_gather_range`] over the same range.
pub fn hierarchical_allreduce_range(
    bufs: &mut [Vec<f32>],
    topo: &Topology,
    prec: TierPrecision,
    lo: usize,
    hi: usize,
) -> WireBytes {
    hierarchical_reduce_scatter_range(bufs, topo, prec, lo, hi)
        + hierarchical_all_gather_range(bufs, topo, prec, lo, hi)
}

struct OwnedChunk<'a> {
    seg: &'a mut [f32],
    first: Option<DType>,
    second: Option<DType>,
}

/// Pooled [`hierarchical_all_gather`]; bit-identical to the serial path.
pub fn hierarchical_all_gather_pooled(
    bufs: &mut [Vec<f32>],
    topo: &Topology,
    prec: TierPrecision,
    pool: &ThreadPool,
) -> WireBytes {
    let mut sp = trace::span(trace::CAT_COMM, "hier_all_gather_pooled");
    let wire = hierarchical_all_gather_pooled_inner(bufs, topo, prec, pool);
    sp.set_detail(wire.total());
    record_wire_metrics(&wire);
    wire
}

fn hierarchical_all_gather_pooled_inner(
    bufs: &mut [Vec<f32>],
    topo: &Topology,
    prec: TierPrecision,
    pool: &ThreadPool,
) -> WireBytes {
    let (w, n) = check_bufs(bufs);
    check_topology(topo, prec, w);
    if !prec.any_half() {
        ring_all_gather_pooled(bufs, pool);
        return hierarchical_phase_wire_bytes(topo, n, prec, true);
    }
    if pool.threads() <= 1 || w < 2 || n < POOLED_MIN_ELEMS {
        // `_inner`, not the public wrapper — same single-count rule as the
        // reduce-scatter fallback above
        return hierarchical_all_gather_inner(bufs, topo, prec);
    }
    let starts = ring_chunk_starts(w, n);
    // one region rounds every owner's chunk (disjoint: one owned chunk per
    // buffer), then the pooled pure-copy gather circulates the values
    let mut tasks: Vec<OwnedChunk<'_>> = bufs
        .iter_mut()
        .enumerate()
        .map(|(b, buf)| {
            let c = (b + 1) % w; // chunk_owner(c, w) == b
            debug_assert_eq!(chunk_owner(c, w), b);
            let (first, second) = owner_roundings(topo, prec, c);
            OwnedChunk { seg: &mut buf[starts[c]..starts[c + 1]], first, second }
        })
        .collect();
    pool.map_mut(&mut tasks, |t| {
        if let Some(d) = t.first {
            round_segment(t.seg, d);
        }
        if let Some(d) = t.second {
            round_segment(t.seg, d);
        }
    });
    drop(tasks);
    ring_all_gather_pooled(bufs, pool);
    hierarchical_phase_wire_bytes(topo, n, prec, true)
}

/// Tiered-ring allreduce: [`hierarchical_reduce_scatter`] then
/// [`hierarchical_all_gather`].  Exact-bit equal to
/// [`ring_allreduce`] when both tiers are fp32 (any topology); all
/// replicas bit-identical for every supported tier precision.
pub fn hierarchical_allreduce(
    bufs: &mut [Vec<f32>],
    topo: &Topology,
    prec: TierPrecision,
) -> WireBytes {
    hierarchical_reduce_scatter(bufs, topo, prec) + hierarchical_all_gather(bufs, topo, prec)
}

/// Pooled [`hierarchical_allreduce`]; bit-identical to the serial path.
pub fn hierarchical_allreduce_pooled(
    bufs: &mut [Vec<f32>],
    topo: &Topology,
    prec: TierPrecision,
    pool: &ThreadPool,
) -> WireBytes {
    hierarchical_reduce_scatter_pooled(bufs, topo, prec, pool)
        + hierarchical_all_gather_pooled(bufs, topo, prec, pool)
}

/// Leader-based two-phase allreduce — the **relaxed-bit-identity mode**
/// (fp32 wire only): per-node ring reduce-scatter over the node's
/// `gpus_per_node` buffers, an inter-node ring allreduce of each local
/// chunk across its per-node owners, then a per-node ring all-gather.
/// This is the executed home of the schedule
/// [`cost::hierarchical_allreduce_shard_aware_time_s`](super::cost::hierarchical_allreduce_shard_aware_time_s)
/// prices (DESIGN.md §8-§9): each NIC carries ~`2N·b` instead of the
/// tiered ring's `~2·gpus_per_node·N·b`.
///
/// **It is deliberately NOT bit-equal to the flat ring** — pre-summing a
/// node regroups the f32 adds (`(a+b)+(c+d)` vs `((a+b)+c)+d`), which is
/// exactly why the default trainer path refuses it unless
/// `relaxed_collectives` is set.  All replicas still end bit-identical to
/// each other, and the result is a deterministic function of the inputs.
/// Returns the executed wire bytes ([`leader_allreduce_wire_bytes`]).
pub fn leader_allreduce(bufs: &mut [Vec<f32>], topo: &Topology) -> WireBytes {
    let mut sp = trace::span(trace::CAT_COMM, "leader_allreduce");
    let wire = leader_allreduce_inner(bufs, topo);
    sp.set_detail(wire.total());
    record_wire_metrics(&wire);
    wire
}

fn leader_allreduce_inner(bufs: &mut [Vec<f32>], topo: &Topology) -> WireBytes {
    let (w, n) = check_bufs(bufs);
    assert_eq!(topo.world(), w, "topology {topo} does not describe {w} buffers");
    if w == 1 || n == 0 {
        return WireBytes::default();
    }
    let (nodes, g) = (topo.nodes, topo.gpus_per_node);
    // local chunk grid shared by all three phases
    let starts = ring_chunk_starts(g, n);
    // phase 1: per-node reduce-scatter (intra tier) — chunk c's node sum
    // lands at local rank chunk_owner(c, g) of every node
    for node in 0..nodes {
        let base = node * g;
        for s in 0..g.saturating_sub(1) {
            for c in 0..g {
                let src = base + (c + s) % g;
                let dst = base + (c + s + 1) % g;
                let (lo, hi) = (starts[c], starts[c + 1]);
                let (a, b) = split_two(bufs, src, dst);
                for i in lo..hi {
                    b[i] += a[i];
                }
            }
        }
    }
    // phase 2: per local chunk, ring-allreduce the node sums across the
    // `nodes` owners (inter tier) — reduce-scatter + all-gather on the
    // chunk's own inter grid
    if nodes > 1 {
        for c in 0..g {
            let o = chunk_owner(c, g);
            let (lo, hi) = (starts[c], starts[c + 1]);
            if lo == hi {
                continue;
            }
            let len = hi - lo;
            let istarts: Vec<usize> = (0..=nodes).map(|k| lo + k * len / nodes).collect();
            for s in 0..nodes - 1 {
                for ic in 0..nodes {
                    let src = ((ic + s) % nodes) * g + o;
                    let dst = ((ic + s + 1) % nodes) * g + o;
                    let (a, b) = split_two(bufs, src, dst);
                    for i in istarts[ic]..istarts[ic + 1] {
                        b[i] += a[i];
                    }
                }
            }
            for s in 0..nodes - 1 {
                for ic in 0..nodes {
                    let src = ((ic + nodes - 1 + s) % nodes) * g + o;
                    let dst = ((ic + nodes + s) % nodes) * g + o;
                    let (a, b) = split_two(bufs, src, dst);
                    b[istarts[ic]..istarts[ic + 1]]
                        .copy_from_slice(&a[istarts[ic]..istarts[ic + 1]]);
                }
            }
        }
    }
    // phase 3: per-node all-gather circulates the owner chunks (intra tier)
    for node in 0..nodes {
        let base = node * g;
        for s in 0..g.saturating_sub(1) {
            for c in 0..g {
                let src = base + (c + g - 1 + s) % g;
                let dst = base + (c + g + s) % g;
                let (lo, hi) = (starts[c], starts[c + 1]);
                let (a, b) = split_two(bufs, src, dst);
                b[lo..hi].copy_from_slice(&a[lo..hi]);
            }
        }
    }
    leader_allreduce_wire_bytes(topo, n)
}

/// Analytic wire bytes of [`leader_allreduce`], summed over all endpoints:
/// `2·nodes·(G−1)·N·b` intra (reduce-scatter + all-gather per node) and
/// `2·(nodes−1)·N·b` inter (each local chunk's inter allreduce moves
/// `2(nodes−1)·len_c`; lengths sum to `N`) — per NIC the inter volume is
/// `2(nodes−1)/nodes·N·b`, the `~G×` cut versus the tiered ring that the
/// shard-aware pricing models.
pub fn leader_allreduce_wire_bytes(topo: &Topology, elems: usize) -> WireBytes {
    if topo.world() <= 1 || elems == 0 {
        return WireBytes::default();
    }
    let b = DType::F32.bytes() as u64;
    WireBytes {
        intra: 2 * topo.nodes as u64 * (topo.gpus_per_node as u64 - 1) * elems as u64 * b,
        inter: 2 * (topo.nodes as u64 - 1) * elems as u64 * b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::half::{ring_allreduce_half, ring_allreduce_wire_bytes};
    use crate::collective::ring::ring_allreduce;
    use crate::util::rng::Rng;

    fn random_bufs(w: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..w).map(|_| (0..n).map(|_| rng.normal_f32()).collect()).collect()
    }

    /// Every (nodes, gpus) factorization of w.
    fn factorizations(w: usize) -> Vec<Topology> {
        (1..=w)
            .filter(|d| w % d == 0)
            .map(|d| Topology::grid(d, w / d))
            .collect()
    }

    #[test]
    fn fp32_tiers_exact_bit_equal_flat_ring_every_topology() {
        for w in [1usize, 2, 4, 6, 8] {
            for n in [0usize, 3, 257, 5000] {
                let template = random_bufs(w, n, (w * 31 + n) as u64);
                let mut reference = template.clone();
                ring_allreduce(&mut reference);
                for topo in factorizations(w) {
                    let mut hier = template.clone();
                    let wire = hierarchical_allreduce(&mut hier, &topo, TierPrecision::fp32());
                    assert_eq!(hier, reference, "{topo} w={w} n={n}");
                    assert_eq!(
                        wire,
                        hierarchical_allreduce_wire_bytes(&topo, n, TierPrecision::fp32()),
                        "{topo} n={n}"
                    );
                }
            }
        }
    }

    #[test]
    fn flat_topology_half_wire_is_the_flat_half_path() {
        // G = 1: every hop inter — identical schedule and bits to the
        // historical ring_allreduce_half at the inter dtype
        for wire in [DType::F16, DType::Bf16] {
            for (w, n) in [(2usize, 100usize), (4, 4099), (5, 3)] {
                let template = random_bufs(w, n, (w * 7 + n) as u64);
                let mut legacy = template.clone();
                let mut tiered = template;
                let lb = ring_allreduce_half(&mut legacy, wire);
                let tb = hierarchical_allreduce(
                    &mut tiered,
                    &Topology::flat(w),
                    TierPrecision::half_inter(wire),
                );
                assert_eq!(legacy, tiered, "{} w={w} n={n}", wire.name());
                assert_eq!(tb.intra, 0);
                assert_eq!(tb.inter, lb);
                assert_eq!(tb.inter, ring_allreduce_wire_bytes(w, n, wire));
            }
        }
    }

    #[test]
    fn half_inter_replicas_bit_identical_and_approximate_sum() {
        for wire in [DType::F16, DType::Bf16] {
            for topo in [Topology::grid(2, 2), Topology::grid(2, 4), Topology::grid(4, 2)] {
                let w = topo.world();
                let n = 1031;
                let mut bufs = random_bufs(w, n, (w * 13 + n) as u64);
                let expect: Vec<f32> =
                    (0..n).map(|i| bufs.iter().map(|b| b[i]).sum()).collect();
                let prec = TierPrecision::half_inter(wire);
                let wb = hierarchical_allreduce(&mut bufs, &topo, prec);
                for b in &bufs[1..] {
                    assert_eq!(&bufs[0], b, "{} {topo} replicas disagree", wire.name());
                }
                assert_eq!(wb, hierarchical_allreduce_wire_bytes(&topo, n, prec), "{topo}");
                // only the scarce hops quantize: the result still tracks
                // the true sum well inside the flat-half tolerance
                let tol = if wire == DType::F16 { 0.1 } else { 0.5 };
                for (got, want) in bufs[0].iter().zip(&expect) {
                    assert!(
                        (got - want).abs() <= tol * want.abs().max(1.0),
                        "{} {topo}: {got} vs {want}",
                        wire.name()
                    );
                }
            }
        }
    }

    #[test]
    fn pooled_matches_serial_bit_for_bit_mixed_tiers() {
        let pool = ThreadPool::new(4);
        for wire in [DType::F16, DType::Bf16] {
            for topo in [Topology::grid(2, 2), Topology::grid(2, 4), Topology::grid(3, 2)] {
                let w = topo.world();
                for n in [10usize, 4099, 30011] {
                    let template = random_bufs(w, n, (w * 17 + n) as u64);
                    let prec = TierPrecision::half_inter(wire);

                    let mut serial = template.clone();
                    let mut pooled = template.clone();
                    let bs = hierarchical_reduce_scatter(&mut serial, &topo, prec);
                    let bp = hierarchical_reduce_scatter_pooled(&mut pooled, &topo, prec, &pool);
                    assert_eq!(serial, pooled, "{} {topo} rs n={n}", wire.name());
                    assert_eq!(bs, bp, "{topo} rs bytes n={n}");
                    assert_eq!(bs, hierarchical_phase_wire_bytes(&topo, n, prec, false));

                    let bs = hierarchical_all_gather(&mut serial, &topo, prec);
                    let bp = hierarchical_all_gather_pooled(&mut pooled, &topo, prec, &pool);
                    assert_eq!(serial, pooled, "{} {topo} ag n={n}", wire.name());
                    assert_eq!(bs, bp, "{topo} ag bytes n={n}");
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_postcondition_matches_flat_owners() {
        // fp32 tiers: the owner chunks after the tiered reduce-scatter are
        // the flat ring's, so step_scattered can consume the buffers as-is
        let topo = Topology::grid(2, 3);
        let (w, n) = (6, 1000);
        let template = random_bufs(w, n, 99);
        let mut flat = template.clone();
        let mut hier = template;
        crate::collective::reduce_scatter::ring_reduce_scatter(&mut flat);
        hierarchical_reduce_scatter(&mut hier, &topo, TierPrecision::fp32());
        let starts = ring_chunk_starts(w, n);
        for c in 0..w {
            let o = chunk_owner(c, w);
            assert_eq!(
                &hier[o][starts[c]..starts[c + 1]],
                &flat[o][starts[c]..starts[c + 1]],
                "chunk {c}"
            );
        }
    }

    #[test]
    fn inter_bytes_shrink_by_gpus_per_node_vs_flat() {
        // the headline invariant, on executed counters: W divisible cases
        // make the shrink exact
        let n = 1 << 12;
        for (nodes, gpus) in [(2usize, 2usize), (2, 4), (4, 2)] {
            let w = nodes * gpus;
            let topo = Topology::grid(nodes, gpus);
            let mut flat_bufs = random_bufs(w, n, 5);
            let mut hier_bufs = flat_bufs.clone();
            let flat =
                hierarchical_allreduce(&mut flat_bufs, &Topology::flat(w), TierPrecision::fp32());
            let hier = hierarchical_allreduce(&mut hier_bufs, &topo, TierPrecision::fp32());
            assert_eq!(flat.intra, 0);
            assert_eq!(hier.inter * gpus as u64, flat.inter, "{topo}");
            assert_eq!(hier.total(), flat.total(), "volume conserved, tiers relabel");
        }
    }

    #[test]
    fn uniform_half_tiers_supported_on_grids() {
        // intra == inter == f16 on a 2x2: every hop quantizes; replicas
        // agree and serial == pooled
        let topo = Topology::grid(2, 2);
        let prec = TierPrecision::uniform(DType::F16);
        let pool = ThreadPool::new(3);
        let template = random_bufs(4, 6000, 23);
        let mut serial = template.clone();
        let mut pooled = template;
        hierarchical_allreduce(&mut serial, &topo, prec);
        hierarchical_allreduce_pooled(&mut pooled, &topo, prec, &pool);
        assert_eq!(serial, pooled);
        for b in &serial[1..] {
            assert_eq!(&serial[0], b);
        }
    }

    #[test]
    fn gathered_values_are_wire_fixed_points() {
        // whatever mix of tiers a chunk crosses, the circulated value must
        // survive requantization at every half format on its path — the
        // single-node uniform-half case (all hops intra) included
        for (topo, prec) in [
            (Topology::grid(1, 4), TierPrecision::uniform(DType::F16)),
            (Topology::grid(2, 2), TierPrecision::uniform(DType::F16)),
            (Topology::grid(2, 2), TierPrecision::half_inter(DType::F16)),
            (Topology::flat(4), TierPrecision::half_inter(DType::F16)),
        ] {
            let mut bufs = random_bufs(topo.world(), 333, 77);
            hierarchical_allreduce(&mut bufs, &topo, prec);
            for b in &bufs[1..] {
                assert_eq!(&bufs[0], b, "{topo} replicas disagree");
            }
            for b in &bufs {
                for &x in b.iter() {
                    assert_eq!(
                        DType::F16.round_trip(x).to_bits(),
                        x.to_bits(),
                        "{topo}: {x} not an f16 fixed point"
                    );
                }
            }
        }
    }

    #[test]
    fn bucketed_range_sweep_equals_full_call_every_precision() {
        // the tentpole contract: per-bucket range collectives over any
        // partition of [0, n) are bitwise identical to the full-vector
        // call, and the per-bucket wire bytes sum to the full counter
        for (topo, prec) in [
            (Topology::flat(4), TierPrecision::fp32()),
            (Topology::grid(2, 2), TierPrecision::fp32()),
            (Topology::grid(2, 3), TierPrecision::half_inter(DType::Bf16)),
            (Topology::grid(2, 4), TierPrecision::half_inter(DType::F16)),
            (Topology::grid(2, 2), TierPrecision::uniform(DType::F16)),
        ] {
            let w = topo.world();
            for n in [10usize, 4099, 30011] {
                let cuts = vec![0, 1.min(n), n / 3, n / 3, (2 * n / 3 + 1).min(n), n];
                let template = random_bufs(w, n, (w * 23 + n) as u64);

                let mut full = template.clone();
                let mut bucketed = template;
                let fb = hierarchical_reduce_scatter(&mut full, &topo, prec);
                let mut bb = WireBytes::default();
                for b in cuts.windows(2) {
                    bb += hierarchical_reduce_scatter_range(&mut bucketed, &topo, prec, b[0], b[1]);
                }
                assert_eq!(full, bucketed, "{topo} rs n={n}");
                assert_eq!(fb, bb, "{topo} rs bytes n={n}");

                let fb = hierarchical_all_gather(&mut full, &topo, prec);
                let mut bb = WireBytes::default();
                for b in cuts.windows(2) {
                    bb += hierarchical_all_gather_range(&mut bucketed, &topo, prec, b[0], b[1]);
                }
                assert_eq!(full, bucketed, "{topo} ag n={n}");
                assert_eq!(fb, bb, "{topo} ag bytes n={n}");
            }
        }
    }

    #[test]
    fn range_wire_bytes_match_analytic() {
        let topo = Topology::grid(2, 2);
        let prec = TierPrecision::half_inter(DType::Bf16);
        let n = 4099;
        let mut bufs = random_bufs(4, n, 3);
        let executed = hierarchical_reduce_scatter_range(&mut bufs, &topo, prec, 17, 3000);
        assert_eq!(
            executed,
            hierarchical_phase_wire_bytes_range(&topo, n, 17, 3000, prec, false)
        );
    }

    #[test]
    fn leader_allreduce_sums_correctly_with_replicas_identical() {
        for topo in [
            Topology::flat(4),
            Topology::grid(2, 2),
            Topology::grid(2, 4),
            Topology::grid(3, 2),
            Topology::grid(4, 1),
            Topology::grid(1, 4),
        ] {
            let w = topo.world();
            for n in [0usize, 7, 1031, 8192] {
                let mut bufs = random_bufs(w, n, (w * 41 + n) as u64);
                let expect: Vec<f64> = (0..n)
                    .map(|i| bufs.iter().map(|b| b[i] as f64).sum())
                    .collect();
                let wire = leader_allreduce(&mut bufs, &topo);
                for b in &bufs[1..] {
                    assert_eq!(&bufs[0], b, "{topo} n={n} replicas disagree");
                }
                assert_eq!(wire, leader_allreduce_wire_bytes(&topo, n), "{topo} n={n}");
                for (got, want) in bufs[0].iter().zip(&expect) {
                    assert!(
                        ((*got as f64) - want).abs() <= 1e-4 * want.abs().max(1.0),
                        "{topo} n={n}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn leader_allreduce_cuts_inter_bytes_by_roughly_gpus_per_node() {
        // the relaxed mode's raison d'être: per-NIC inter volume drops by
        // ~G versus the (bit-exact) tiered ring
        for (nodes, gpus) in [(2usize, 4usize), (4, 8)] {
            let topo = Topology::grid(nodes, gpus);
            let n = 1 << 14;
            let tiered = hierarchical_allreduce_wire_bytes(&topo, n, TierPrecision::fp32());
            let leader = leader_allreduce_wire_bytes(&topo, n);
            let ratio = tiered.inter as f64 / leader.inter as f64;
            let expect = (topo.world() - 1) as f64 / (nodes - 1) as f64;
            assert!((ratio - expect).abs() < 1e-9, "{topo}: {ratio} vs {expect}");
        }
    }

    #[test]
    fn leader_allreduce_is_not_the_flat_ring_reduction_order() {
        // document the relaxation: with >1 gpus per node the regrouped f32
        // adds generically differ from the flat ring's — this is why the
        // trainer gates the path behind `relaxed_collectives`
        let topo = Topology::grid(2, 2);
        let template = random_bufs(4, 257, 12);
        let mut flat = template.clone();
        let mut leader = template;
        ring_allreduce(&mut flat);
        leader_allreduce(&mut leader, &topo);
        assert_ne!(flat, leader, "expected regrouped f32 sums to differ somewhere");
    }

    #[test]
    #[should_panic(expected = "tier precision")]
    fn mismatched_half_tiers_rejected() {
        let mut bufs = vec![vec![0.0f32; 8]; 4];
        hierarchical_allreduce(
            &mut bufs,
            &Topology::grid(2, 2),
            TierPrecision { intra: DType::F16, inter: DType::Bf16 },
        );
    }

    #[test]
    #[should_panic(expected = "does not describe")]
    fn topology_world_must_match_buffer_count() {
        let mut bufs = vec![vec![0.0f32; 8]; 3];
        hierarchical_reduce_scatter(&mut bufs, &Topology::grid(2, 2), TierPrecision::fp32());
    }

    #[test]
    fn width_one_is_identity() {
        let topo = Topology::grid(1, 1);
        let mut bufs = vec![vec![0.25f32, -1.0, 3.0]];
        let orig = bufs.clone();
        let wb = hierarchical_allreduce(&mut bufs, &topo, TierPrecision::half_inter(DType::F16));
        assert_eq!(bufs, orig);
        assert_eq!(wb, WireBytes::default());
    }
}
