//! Minimal JSON parser — the runtime's reader for `artifacts/*.meta.json`.
//!
//! serde_json is unavailable offline, and the meta files are small and
//! machine-generated, so a compact recursive-descent parser is the right
//! tool.  Supports the full JSON grammar (objects, arrays, strings with
//! escapes, numbers, bools, null); errors carry byte offsets.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; panics with a useful message if the
    /// path is missing (meta files are trusted, machine-written inputs).
    pub fn expect(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // surrogate pairs: only BMP needed for meta files,
                            // but handle pairs for completeness
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let hex2 = std::str::from_utf8(
                                        &self.b[self.i..self.i + 4],
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 4;
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                cp
                            };
                            out.push(
                                char::from_u32(ch)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(
            v.expect("a").as_arr().unwrap()[2].expect("b").as_str(),
            Some("c")
        );
        assert_eq!(v.expect("d"), &Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_and_whitespace() {
        let v = Json::parse(" { \"k\" : \"héllo\" } ").unwrap();
        assert_eq!(v.expect("k").as_str(), Some("héllo"));
        // astral plane via surrogate pair
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn roundtrips_meta_shape() {
        let s = r#"{"params": [{"name": "w", "shape": [3, 4], "size": 12,
                     "decay": true}], "batch": 4}"#;
        let v = Json::parse(s).unwrap();
        let p = &v.expect("params").as_arr().unwrap()[0];
        assert_eq!(p.expect("size").as_usize(), Some(12));
        assert_eq!(p.expect("decay").as_bool(), Some(true));
    }
}
