//! Deterministic PRNG: splitmix64 seeding + xoshiro256** core.
//!
//! The `rand` crate is unavailable offline, and determinism across the whole
//! stack (data sharding, masking, synthetic corpora, tests) is a feature:
//! every experiment in EXPERIMENTS.md pins a seed, and re-runs are
//! bit-reproducible.  xoshiro256** is the same generator family numpy's
//! default_rng is built on (PCG/xoshiro class), adequate for simulation.

/// splitmix64 — used to expand a u64 seed into xoshiro state and to derive
/// independent per-worker / per-shard streams.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** by Blackman & Vigna.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream, e.g. per worker: `rng.fork(worker_id)`.
    pub fn fork(&self, stream: u64) -> Self {
        // mix the current state with the stream id through splitmix
        let mut sm = self.s[0] ^ self.s[2] ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached second value omitted for
    /// simplicity; the data path uses this only at init time).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (floyd's algorithm when k is
    /// small relative to n, else shuffle-prefix).
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            // Floyd's sampling
            let mut set = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.below_usize(j + 1);
                if set.insert(t) {
                    out.push(t);
                } else {
                    set.insert(j);
                    out.push(j);
                }
            }
            out
        }
    }

    /// Sample `k` indices from [0, n) with replacement.
    pub fn sample_with_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.below_usize(n)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_independent() {
        let root = Rng::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.below(17);
            assert!(x < 17);
        }
        // rough uniformity
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.below(8) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn without_replacement_distinct() {
        let mut r = Rng::new(5);
        for (n, k) in [(100, 10), (50, 50), (1000, 3)] {
            let s = r.sample_without_replacement(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates for n={n} k={k}");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
