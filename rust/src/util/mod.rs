//! In-tree substrates for crates unavailable offline (serde_json, rand,
//! criterion): a JSON parser, a deterministic PRNG, statistics helpers and
//! a bench harness.

pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;
