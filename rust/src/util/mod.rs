//! In-tree substrates for crates unavailable offline (serde_json, rand,
//! criterion, rayon): a JSON parser, a deterministic PRNG, statistics
//! helpers, a bench harness (with a machine-readable reporter), and a
//! persistent worker pool.

pub mod bench;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
