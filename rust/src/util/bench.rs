//! Bench harness — criterion is unavailable offline, so benches run with
//! `harness = false` and use this module: warmup, repeated timed runs,
//! mean / p50 / p99, aligned table printing so every `rust/benches/*.rs`
//! regenerates its paper table with the same look, and a shared
//! [`Reporter`] that persists every hot-path bench's numbers to
//! `BENCH_<name>.json` so the perf trajectory survives across PRs instead
//! of scrolling away in CI logs.
//!
//! The hot-path benches also honour a `--quick` flag (or `BENCH_QUICK=1`)
//! — fewer iterations, smaller sweeps, *same assertions* — so CI can
//! execute the speedup checks instead of only compiling them.

use std::io::Write as _;
use std::path::PathBuf;
use std::time::Instant;

use crate::util::stats::percentile;

/// True when the bench binary was invoked with `--quick` (e.g.
/// `cargo bench --bench optimizer_step -- --quick`) or with
/// `BENCH_QUICK` set to anything but `0`/empty — the CI smoke mode.
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_QUICK").is_ok_and(|v| !v.is_empty() && v != "0")
}

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: percentile(&samples, 50.0),
        p99_ns: percentile(&samples, 99.0),
    }
}

pub fn print_result(r: &BenchResult) {
    println!(
        "{:<44} {:>10.3} ms mean   {:>10.3} ms p50   {:>10.3} ms p99   ({} iters)",
        r.name,
        r.mean_ns / 1e6,
        r.p50_ns / 1e6,
        r.p99_ns / 1e6,
        r.iters
    );
}

/// Markdown-style table printer used by the paper-table benches.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        let mut sep = String::from("|");
        for wi in &w {
            sep.push_str(&format!("{}|", "-".repeat(wi + 2)));
        }
        println!("{sep}");
        for r in &self.rows {
            println!("{}", line(r));
        }
    }
}

/// Machine-readable bench reporter.  Collects timed results and free-form
/// scalar metrics, then writes one `BENCH_<name>.json` file (into
/// `$BENCH_OUT_DIR`, or the working directory) with a flat, stable schema:
///
/// ```json
/// {
///   "bench": "optimizer_step",
///   "quick": false,
///   "threads_available": 8,
///   "results": [{"name": "...", "iters": 10,
///                "mean_ms": 1.2, "p50_ms": 1.1, "p99_ms": 1.9}],
///   "metrics": {"pool_speedup_t4": 3.7}
/// }
/// ```
///
/// The writer is the *only* JSON producer in the repo (the in-tree
/// `util::json` is a parser), so escaping lives here: names are
/// code-controlled ASCII, non-finite floats serialize as `null`.
pub struct Reporter {
    bench: String,
    results: Vec<(String, usize, f64, f64, f64)>,
    metrics: Vec<(String, f64)>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:?}")
    } else {
        "null".to_string()
    }
}

impl Reporter {
    pub fn new(bench: &str) -> Reporter {
        Reporter { bench: bench.to_string(), results: Vec::new(), metrics: Vec::new() }
    }

    /// Record a timed result under its own name.
    pub fn result(&mut self, r: &BenchResult) {
        self.results.push((
            r.name.clone(),
            r.iters,
            r.mean_ns / 1e6,
            r.p50_ns / 1e6,
            r.p99_ns / 1e6,
        ));
    }

    /// Record a free-form scalar (speedup ratios, thread counts, sizes).
    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.push((name.to_string(), value));
    }

    fn render(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(&self.bench)));
        s.push_str(&format!("  \"quick\": {},\n", quick_mode()));
        s.push_str(&format!(
            "  \"threads_available\": {},\n",
            crate::util::pool::ThreadPool::available()
        ));
        s.push_str("  \"results\": [\n");
        for (i, (name, iters, mean, p50, p99)) in self.results.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"iters\": {}, \"mean_ms\": {}, \
                 \"p50_ms\": {}, \"p99_ms\": {}}}{}\n",
                json_escape(name),
                iters,
                json_num(*mean),
                json_num(*p50),
                json_num(*p99),
                if i + 1 < self.results.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"metrics\": {\n");
        for (i, (name, value)) in self.metrics.iter().enumerate() {
            s.push_str(&format!(
                "    \"{}\": {}{}\n",
                json_escape(name),
                json_num(*value),
                if i + 1 < self.metrics.len() { "," } else { "" }
            ));
        }
        s.push_str("  }\n}\n");
        s
    }

    /// Write `BENCH_<bench>.json` and return its path.  Benches call this
    /// *before* their acceptance assertions so a failing run still leaves
    /// its numbers behind.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = std::env::var_os("BENCH_OUT_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("."));
        let path = dir.join(format!("BENCH_{}.json", self.bench));
        let mut f = std::fs::File::create(&path)?;
        f.write_all(self.render().as_bytes())?;
        eprintln!("[bench json -> {}]", path.display());
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns * 0.5);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // smoke: no panic
    }

    #[test]
    fn reporter_renders_parseable_json() {
        let mut rep = Reporter::new("unit_test");
        rep.result(&BenchResult {
            name: "case \"a\"".into(),
            iters: 3,
            mean_ns: 1.5e6,
            p50_ns: 1.4e6,
            p99_ns: 2.0e6,
        });
        rep.metric("speedup", 2.5);
        rep.metric("bad", f64::NAN); // must serialize as null, not NaN
        let s = rep.render();
        let v = crate::util::json::Json::parse(&s).expect("reporter output must parse");
        assert_eq!(v.expect("bench").as_str(), Some("unit_test"));
        let results = v.expect("results").as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].expect("name").as_str(), Some("case \"a\""));
        assert_eq!(results[0].expect("iters").as_usize(), Some(3));
        assert!((results[0].expect("mean_ms").as_f64().unwrap() - 1.5).abs() < 1e-12);
        let metrics = v.expect("metrics");
        assert_eq!(metrics.expect("speedup").as_f64(), Some(2.5));
    }

    #[test]
    fn reporter_handles_empty_sections() {
        let rep = Reporter::new("empty");
        let s = rep.render();
        assert!(crate::util::json::Json::parse(&s).is_ok(), "bad json: {s}");
    }
}
