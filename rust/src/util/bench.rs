//! Bench harness — criterion is unavailable offline, so benches run with
//! `harness = false` and use this module: warmup, repeated timed runs,
//! mean / p50 / p99, and aligned table printing so every `rust/benches/*.rs`
//! regenerates its paper table with the same look.

use std::time::Instant;

use crate::util::stats::percentile;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: percentile(&samples, 50.0),
        p99_ns: percentile(&samples, 99.0),
    }
}

pub fn print_result(r: &BenchResult) {
    println!(
        "{:<44} {:>10.3} ms mean   {:>10.3} ms p50   {:>10.3} ms p99   ({} iters)",
        r.name,
        r.mean_ns / 1e6,
        r.p50_ns / 1e6,
        r.p99_ns / 1e6,
        r.iters
    );
}

/// Markdown-style table printer used by the paper-table benches.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        let mut sep = String::from("|");
        for wi in &w {
            sep.push_str(&format!("{}|", "-".repeat(wi + 2)));
        }
        println!("{sep}");
        for r in &self.rows {
            println!("{}", line(r));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns * 0.5);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // smoke: no panic
    }
}
