//! A persistent worker pool for the training hot path.
//!
//! The pool hands out *borrowed* work items — each worker receives
//! `&mut I` for a disjoint item — which is exactly what plan-sharded
//! optimizer updates and chunk-parallel collectives need: disjoint mutable
//! slices over the flat parameter/gradient vectors, no `Arc`, no copies.
//!
//! One training step issues many small parallel regions (two to three for
//! the optimizer phases plus `2(W-1)` for the ring collective), so region
//! overhead *is* the hot path.  Workers are therefore long-lived threads
//! parked on a condvar, not per-call `std::thread::scope` spawns:
//!
//! * **Region = two synchronization points.**  [`ThreadPool::map_mut`]
//!   publishes a region under the pool mutex (one lock + wakeups) and
//!   closes it under the same mutex (one lock + a generation-counted
//!   barrier that waits only for the workers that actually engaged).  The
//!   per-call-spawn baseline pays N `clone`+spawn+join syscalls instead —
//!   [`ThreadPool::new_spawning`] keeps that implementation alive purely so
//!   the `optimizer_step` bench can measure the difference.
//! * **Lock-free-ish task queue.**  Work is a pre-split task list (one
//!   entry per disjoint item); workers claim indices with one
//!   `fetch_add` each — no `Mutex<Iterator>` pop per item — and write
//!   results into per-index slots — no `Mutex<Option<T>>` per result.
//! * **Generation counter.**  Each region bumps a generation; a worker
//!   joins a region at most once (it records the generation it served) and
//!   a region only waits on workers that joined it, so a still-parked
//!   worker can never touch a region that has already been closed, and a
//!   small region does not pay a full-pool barrier.
//! * **Panic containment.**  A panicking work item marks the region
//!   poisoned; every engaged worker still checks out (no hang, workers
//!   stay parked and reusable) and the *caller* panics after the barrier.
//! * `threads == 1` (or fewer than two items) never spawns and never did:
//!   that path is a plain serial loop, bit-identical to the pooled one.
//!
//! Safety model: a region's closure borrows the caller's stack (items,
//! result slots, `f`).  The lifetime is erased to hand it to the long-lived
//! workers, which is sound because `map_mut` does not return until every
//! worker that observed the region has checked out under the pool mutex —
//! the borrow never outlives the call.  Results are written through
//! per-index raw slots claimed by exactly one worker (the `fetch_add`
//! makes indices unique), and the closing mutex acquisition makes all
//! worker writes visible to the caller.

use std::any::Any;
use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

thread_local! {
    /// True while this thread is executing a region work item.  A nested
    /// [`ThreadPool::map_mut`] issued from inside a work item (on any
    /// persistent pool) runs serially instead of publishing a region —
    /// the nested publish would otherwise wait on the region slot that
    /// its own caller holds open, a silent deadlock the old per-call
    /// scoped pool did not have.
    static IN_REGION: Cell<bool> = const { Cell::new(false) };
}

pub mod policy {
    //! Serial-fallback policy — the one home for every "is this enough
    //! work to engage the pool?" threshold, so the trainer, the plan
    //! executor and the collectives cannot drift apart.
    //!
    //! Rationale: a persistent-pool region costs two mutex passes plus the
    //! wakeup latency of the engaged workers (~µs-class), where the old
    //! scoped pool paid a spawn+join per worker (~100µs-class).  The
    //! thresholds below predate the persistent pool and are kept at their
    //! measured values: they now mark the point where a region's *barrier*
    //! cost (not spawn cost) exceeds the sharded compute, and keeping them
    //! stable keeps every existing serial-vs-pooled test boundary intact.

    /// Below this many total parameters an optimizer step is cheaper
    /// serial than as pool regions; `ParallelExecutor::step` falls back
    /// automatically (results are identical either way).
    pub const PARALLEL_MIN_ELEMS: usize = 1 << 16;

    /// Below this buffer length a ring collective's per-step regions cost
    /// more than the chunk work; the pooled collectives and the sharded
    /// optimizer fall back to the serial schedule (identical results).
    pub const POOLED_MIN_ELEMS: usize = 1 << 12;

    /// Chunks per pool thread for the plan-granularity executor: the
    /// balanced `ShardPlan` over-partitions the flat vector by this factor
    /// so dynamic scheduling can absorb chunk-cost skew (the last chunks
    /// of a block carry partial segments) without a static-partition tail.
    pub const PLAN_CHUNKS_PER_THREAD: usize = 8;

    /// Number of plan chunks the plan-granularity executor cuts for a
    /// `threads`-wide pool.
    pub fn plan_chunks(threads: usize) -> usize {
        threads.max(1) * PLAN_CHUNKS_PER_THREAD
    }
}

/// One parallel region, lifetime-erased for the long-lived workers.  Lives
/// on the caller's stack for exactly the duration of the region (see the
/// module safety model).
struct Region {
    /// type- and lifetime-erased task body: `run(i)` executes task `i`
    run: *const (dyn Fn(usize) + Sync),
    /// number of tasks in the pre-split list
    count: usize,
    /// next unclaimed task index — the whole queue is this one atomic
    cursor: AtomicUsize,
    /// workers currently engaged with *this* region (joined under the
    /// pool mutex, checked out under it); the close barrier waits for 0
    engaged: AtomicUsize,
    /// set by any worker whose task panicked; the caller re-panics
    poisoned: AtomicBool,
    /// the first panicking task's payload, resumed by the caller after
    /// the close barrier so the original message/location survive
    payload: Mutex<Option<Box<dyn Any + Send>>>,
}

/// Erase the borrow's lifetime so the long-lived workers can hold the
/// pointer.  Sound only because [`run_region`] does not return until every
/// worker that observed the region has checked out.
#[allow(clippy::transmutes_expressible_as_ptr_casts)]
fn erase<'a>(run: &'a (dyn Fn(usize) + Sync + 'a)) -> *const (dyn Fn(usize) + Sync + 'static) {
    // SAFETY: fat-pointer transmute between the same trait object with a
    // shorter vs 'static lifetime bound; layout is identical.
    unsafe {
        std::mem::transmute::<
            &'a (dyn Fn(usize) + Sync + 'a),
            *const (dyn Fn(usize) + Sync + 'static),
        >(run)
    }
}

/// Raw pointer to the active region, made sendable: workers only ever
/// dereference it between joining and checking out, both under the pool
/// mutex protocol that keeps the caller alive for that window.
#[derive(Clone, Copy)]
struct RegionPtr(*const Region);
unsafe impl Send for RegionPtr {}

struct PoolState {
    /// the currently open region, if any
    region: Option<RegionPtr>,
    /// bumped once per region; a worker serves each generation at most once
    generation: u64,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// workers park here waiting for a new generation
    work_cv: Condvar,
    /// the caller parks here waiting for engaged workers to check out
    /// (and queued callers wait here for the region slot to free up)
    done_cv: Condvar,
}

/// Owns the worker threads.  Dropped when the last [`ThreadPool`] clone
/// drops: signals shutdown and joins the (parked) workers.
struct PoolCore {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl Drop for PoolCore {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let mut served = 0u64;
    loop {
        // park until a generation we have not served opens (or shutdown);
        // joining (the engaged increment) happens under the lock, so the
        // region cannot close while we take it
        let region = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != served {
                    if let Some(r) = st.region {
                        served = st.generation;
                        // SAFETY: region open ⇒ its caller is inside
                        // run_region, the stack referent is alive
                        unsafe { &*r.0 }.engaged.fetch_add(1, Ordering::Relaxed);
                        break r;
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // drain tasks: one fetch_add per claim, body runs lock-free
        let r = unsafe { &*region.0 };
        IN_REGION.with(|c| c.set(true));
        let busy = crate::trace::span(crate::trace::CAT_POOL, "worker_busy");
        let busy_t0 = crate::metrics::registry::enabled().then(std::time::Instant::now);
        loop {
            let i = r.cursor.fetch_add(1, Ordering::Relaxed);
            if i >= r.count {
                break;
            }
            let run = unsafe { &*r.run };
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| run(i))) {
                r.poisoned.store(true, Ordering::Relaxed);
                let mut slot = r.payload.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
        }
        drop(busy);
        if let Some(t0) = busy_t0 {
            crate::metrics::registry::POOL_BUSY_US.add(t0.elapsed().as_micros() as u64);
        }
        IN_REGION.with(|c| c.set(false));
        // check out under the lock; the closing caller waits for 0 and
        // frees the region only after, so `r` is never touched again
        let st = shared.state.lock().unwrap();
        r.engaged.fetch_sub(1, Ordering::Relaxed);
        shared.done_cv.notify_all();
        drop(st);
    }
}

enum Backend {
    /// width 1: plain serial loop, nothing ever spawned
    Serial,
    /// long-lived parked workers (the default for width ≥ 2)
    Persistent(Arc<PoolCore>),
    /// per-call `std::thread::scope` spawn — the legacy implementation,
    /// kept only as the baseline the `optimizer_step` bench beats
    Spawn,
}

/// Fixed-width worker pool.  Construct once per trainer/executor and call
/// [`ThreadPool::map_mut`] per parallel region; clones share the same
/// workers.  Width `w ≥ 2` keeps `w - 1` threads parked — the calling
/// thread is the `w`-th worker of every region.
pub struct ThreadPool {
    threads: usize,
    backend: Backend,
}

impl Clone for ThreadPool {
    fn clone(&self) -> ThreadPool {
        let backend = match &self.backend {
            Backend::Serial => Backend::Serial,
            Backend::Persistent(core) => Backend::Persistent(core.clone()),
            Backend::Spawn => Backend::Spawn,
        };
        ThreadPool { threads: self.threads, backend }
    }
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = match self.backend {
            Backend::Serial => "serial",
            Backend::Persistent(_) => "persistent",
            Backend::Spawn => "spawn",
        };
        write!(f, "ThreadPool {{ threads: {}, backend: {kind} }}", self.threads)
    }
}

impl ThreadPool {
    /// A pool with `threads` workers; `0` selects the machine's available
    /// parallelism.  The width is clamped to at least 1; width 1 spawns
    /// nothing, width `w ≥ 2` parks `w - 1` persistent workers.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = if threads == 0 { Self::available() } else { threads };
        let threads = threads.max(1);
        if threads == 1 {
            return ThreadPool { threads, backend: Backend::Serial };
        }
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                region: None,
                generation: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..threads - 1)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("lans-pool-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawning pool worker")
            })
            .collect();
        ThreadPool {
            threads,
            backend: Backend::Persistent(Arc::new(PoolCore { shared, handles })),
        }
    }

    /// The legacy per-call-spawn pool: same API, same results, but every
    /// [`map_mut`](Self::map_mut) pays a scoped spawn+join per worker.
    /// Exists only so the `optimizer_step` bench can quantify what the
    /// persistent pool removes; never used on the training path.
    pub fn new_spawning(threads: usize) -> ThreadPool {
        let threads = if threads == 0 { Self::available() } else { threads };
        let threads = threads.max(1);
        let backend = if threads == 1 { Backend::Serial } else { Backend::Spawn };
        ThreadPool { threads, backend }
    }

    /// The machine's available parallelism (1 if unknown).
    pub fn available() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Apply `f` to every item, distributing items across the pool's
    /// workers (the caller included), dynamically: a skewed task list does
    /// not serialize on a bad static partition.  Results are returned in
    /// item order regardless of which worker ran what, so reductions that
    /// combine them stay deterministic.  Runs serially (no other threads
    /// touched) when the pool is width-1 or there are fewer than two
    /// items.
    ///
    /// If any item's `f` panics the region is poisoned: remaining items
    /// may be skipped, every engaged worker still checks out, and this
    /// call re-raises the first panic once the region has closed (items
    /// may be left partially mutated, as with any panic mid-mutation).
    ///
    /// Reentrancy: a `map_mut` issued from *inside* a work item (any
    /// persistent pool) runs its items serially on the current thread —
    /// the nested publish would otherwise deadlock on the region slot its
    /// own caller holds open.  Results are identical either way.
    pub fn map_mut<I, T, F>(&self, items: &mut [I], f: F) -> Vec<T>
    where
        I: Send,
        T: Send,
        F: Fn(&mut I) -> T + Sync,
    {
        let n = items.len();
        if self.threads <= 1 || n <= 1 {
            return items.iter_mut().map(f).collect();
        }
        match &self.backend {
            Backend::Serial => items.iter_mut().map(f).collect(),
            Backend::Spawn => map_mut_spawning(self.threads, items, f),
            Backend::Persistent(core) => {
                if IN_REGION.with(|c| c.get()) {
                    // nested region from inside a work item: publishing
                    // would deadlock on the slot our own caller holds —
                    // run serially instead (identical results)
                    return items.iter_mut().map(f).collect();
                }
                let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
                {
                    let items_ptr = SendSyncPtr(items.as_mut_ptr());
                    let slots_ptr = SendSyncPtr(slots.as_mut_ptr());
                    let run = |i: usize| {
                        // each index is claimed exactly once (fetch_add),
                        // so these derefs are disjoint across workers
                        let item: &mut I = unsafe { &mut *items_ptr.0.add(i) };
                        let out = f(item);
                        unsafe { *slots_ptr.0.add(i) = Some(out) };
                    };
                    run_region(&core.shared, n, &run);
                }
                slots
                    .into_iter()
                    .map(|s| s.expect("pool worker lost a result"))
                    .collect()
            }
        }
    }
}

/// Raw pointer that may cross threads; disjointness of the indexed
/// accesses is guaranteed by the region's task-claim protocol.
struct SendSyncPtr<T>(*mut T);
unsafe impl<T> Send for SendSyncPtr<T> {}
unsafe impl<T> Sync for SendSyncPtr<T> {}

/// Execute one region on the persistent workers: publish (sync point 1),
/// have the caller drain tasks alongside the workers, close (sync point
/// 2: wait for engaged workers to check out).
fn run_region(shared: &Shared, count: usize, run: &(dyn Fn(usize) + Sync)) {
    // metrics seam: region count + open-region wall time (dispatch→close);
    // utilization = pool.busy_us / (pool.region_us × workers)
    let region_t0 = crate::metrics::registry::enabled().then(std::time::Instant::now);
    let region = Region {
        run: erase(run),
        count,
        cursor: AtomicUsize::new(0),
        engaged: AtomicUsize::new(0),
        poisoned: AtomicBool::new(false),
        payload: Mutex::new(None),
    };

    // publish: one mutex pass + wakeups.  If another thread's region is
    // still open (pools are shared), queue behind it.
    {
        let _sp = crate::trace::span(crate::trace::CAT_POOL, "region_dispatch");
        let mut st = shared.state.lock().unwrap();
        while st.region.is_some() {
            st = shared.done_cv.wait(st).unwrap();
        }
        st.region = Some(RegionPtr(&region as *const Region));
        st.generation = st.generation.wrapping_add(1);
        shared.work_cv.notify_all();
    }

    // the caller is a worker too: claim and run tasks until none remain
    IN_REGION.with(|c| c.set(true));
    let drain = crate::trace::span(crate::trace::CAT_POOL, "region_drain");
    let drain_t0 = crate::metrics::registry::enabled().then(std::time::Instant::now);
    let caller_panic = loop {
        let i = region.cursor.fetch_add(1, Ordering::Relaxed);
        if i >= count {
            break None;
        }
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| run(i))) {
            region.poisoned.store(true, Ordering::Relaxed);
            break Some(payload);
        }
    };
    drop(drain);
    if let Some(t0) = drain_t0 {
        // the caller's drain is busy time too — it is the w-th worker
        crate::metrics::registry::POOL_BUSY_US.add(t0.elapsed().as_micros() as u64);
    }
    IN_REGION.with(|c| c.set(false));

    // close: retract the region so no new worker joins (and the slot
    // frees for queued callers), then wait for this region's engaged
    // workers to check out.  After this, no thread can touch `region` (or
    // the caller's borrows inside `run`) again.
    {
        let _sp = crate::trace::span(crate::trace::CAT_WAIT, "region_close");
        let mut st = shared.state.lock().unwrap();
        st.region = None;
        shared.done_cv.notify_all();
        while region.engaged.load(Ordering::Relaxed) > 0 {
            st = shared.done_cv.wait(st).unwrap();
        }
        drop(st);
    }

    if let Some(t0) = region_t0 {
        crate::metrics::registry::POOL_REGIONS.add(1);
        crate::metrics::registry::POOL_REGION_US.add(t0.elapsed().as_micros() as u64);
    }

    if let Some(payload) = caller_panic {
        // the flight recorder seals its postmortem bundle before the panic
        // leaves this frame — after the unwind there is nobody left to ask
        crate::obs::flight::note_panic("pool", "pool_region");
        std::panic::resume_unwind(payload);
    }
    if region.poisoned.load(Ordering::Relaxed) {
        crate::obs::flight::note_panic("pool", "pool_region");
        // resume the first worker's payload so the original panic
        // message and location survive the thread hop
        if let Some(payload) = region.payload.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
        panic!("ThreadPool region poisoned: a work item panicked on a pool worker");
    }
}

/// The legacy scoped-thread implementation (per-call spawn + join,
/// `Mutex<Iterator>` task pop, `Mutex<Option<T>>` result slots) — the
/// baseline [`ThreadPool::new_spawning`] preserves for the bench.
fn map_mut_spawning<I, T, F>(threads: usize, items: &mut [I], f: F) -> Vec<T>
where
    I: Send,
    T: Send,
    F: Fn(&mut I) -> T + Sync,
{
    let n = items.len();
    let workers = threads.min(n);
    let queue = Mutex::new(items.iter_mut().enumerate());
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let next = queue.lock().unwrap().next();
                match next {
                    Some((i, item)) => {
                        let out = f(item);
                        *slots[i].lock().unwrap() = Some(out);
                    }
                    None => break,
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("pool worker lost a result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_means_available() {
        assert_eq!(ThreadPool::new(0).threads(), ThreadPool::available());
        assert_eq!(ThreadPool::new(3).threads(), 3);
    }

    #[test]
    fn map_mut_matches_serial_and_preserves_order() {
        let mut a: Vec<u64> = (0..97).collect();
        let mut b = a.clone();
        let serial: Vec<u64> = ThreadPool::new(1).map_mut(&mut a, |x| {
            *x += 1;
            *x * 2
        });
        let parallel: Vec<u64> = ThreadPool::new(4).map_mut(&mut b, |x| {
            *x += 1;
            *x * 2
        });
        assert_eq!(serial, parallel);
        assert_eq!(a, b);
        assert_eq!(serial[10], 22);
    }

    #[test]
    fn mutates_disjoint_slices() {
        let mut data = vec![1.0f32; 64];
        let mut chunks: Vec<&mut [f32]> = data.chunks_mut(7).collect();
        let sums = ThreadPool::new(8).map_mut(&mut chunks, |c| {
            for x in c.iter_mut() {
                *x *= 2.0;
            }
            c.len()
        });
        assert_eq!(sums.iter().sum::<usize>(), 64);
        assert!(data.iter().all(|&x| x == 2.0));
    }

    #[test]
    fn more_threads_than_items() {
        let mut items = vec![5usize, 6];
        let out = ThreadPool::new(16).map_mut(&mut items, |x| *x * 10);
        assert_eq!(out, vec![50, 60]);
    }

    #[test]
    fn empty_items() {
        let mut items: Vec<usize> = Vec::new();
        let out: Vec<usize> = ThreadPool::new(4).map_mut(&mut items, |x| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn pool_is_reusable_across_many_regions() {
        // the persistent-pool point: one pool, many cheap regions.  Every
        // region's results must be correct and in item order.
        let pool = ThreadPool::new(4);
        for round in 0..200u64 {
            let mut items: Vec<u64> = (0..(1 + round % 13)).collect();
            let out = pool.map_mut(&mut items, |x| *x + round);
            let want: Vec<u64> = (0..(1 + round % 13)).map(|i| i + round).collect();
            assert_eq!(out, want, "round {round}");
        }
    }

    #[test]
    fn clones_share_workers_and_agree() {
        let a = ThreadPool::new(3);
        let b = a.clone();
        let mut xs: Vec<u32> = (0..50).collect();
        let mut ys = xs.clone();
        assert_eq!(a.map_mut(&mut xs, |x| *x * 3), b.map_mut(&mut ys, |x| *x * 3));
    }

    #[test]
    fn spawning_baseline_matches_persistent() {
        let persistent = ThreadPool::new(4);
        let spawning = ThreadPool::new_spawning(4);
        let mut a: Vec<u64> = (0..64).collect();
        let mut b = a.clone();
        let ra = persistent.map_mut(&mut a, |x| {
            *x *= 5;
            *x
        });
        let rb = spawning.map_mut(&mut b, |x| {
            *x *= 5;
            *x
        });
        assert_eq!(ra, rb);
        assert_eq!(a, b);
    }

    #[test]
    fn panicking_item_poisons_region_but_not_the_pool() {
        let pool = ThreadPool::new(4);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut items: Vec<usize> = (0..64).collect();
            pool.map_mut(&mut items, |x| {
                if *x == 13 {
                    panic!("boom");
                }
                *x
            });
        }));
        assert!(result.is_err(), "poisoned region must panic the caller");
        // workers must still be parked and serviceable, not hung or dead
        let mut items: Vec<usize> = (0..32).collect();
        let out = pool.map_mut(&mut items, |x| *x + 1);
        assert_eq!(out, (1..33).collect::<Vec<_>>());
    }

    #[test]
    fn concurrent_regions_queue_behind_each_other() {
        // two threads sharing one pool: regions serialize on the region
        // slot, both complete correctly
        let pool = ThreadPool::new(3);
        std::thread::scope(|s| {
            for t in 0..2u64 {
                let pool = pool.clone();
                s.spawn(move || {
                    for round in 0..50u64 {
                        let mut items: Vec<u64> = (0..9).collect();
                        let out = pool.map_mut(&mut items, |x| *x + t * 1000 + round);
                        let want: Vec<u64> =
                            (0..9).map(|i| i + t * 1000 + round).collect();
                        assert_eq!(out, want);
                    }
                });
            }
        });
    }

    #[test]
    fn nested_map_mut_runs_serially_instead_of_deadlocking() {
        let pool = ThreadPool::new(3);
        let inner = pool.clone();
        let mut items: Vec<u64> = (0..8).collect();
        let out = pool.map_mut(&mut items, |x| {
            // nested region from inside a work item: must not hang
            let mut sub: Vec<u64> = (0..4).map(|i| *x + i).collect();
            inner.map_mut(&mut sub, |y| *y * 2).iter().sum::<u64>()
        });
        let want: Vec<u64> = (0..8).map(|x| (0..4).map(|i| (x + i) * 2).sum()).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn worker_panic_payload_survives() {
        // the original panic message must reach the caller even when the
        // panicking task ran on a pool worker, not the calling thread
        let pool = ThreadPool::new(4);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut items: Vec<usize> = (0..64).collect();
            pool.map_mut(&mut items, |x| {
                if *x == 13 {
                    panic!("distinctive-payload-13");
                }
                *x
            });
        }));
        let payload = result.unwrap_err();
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .map(str::to_owned)
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(
            msg.contains("distinctive-payload-13"),
            "payload lost: {msg:?}"
        );
    }

    #[test]
    fn policy_constants_are_sane() {
        assert!(policy::PARALLEL_MIN_ELEMS > policy::POOLED_MIN_ELEMS);
        assert_eq!(policy::plan_chunks(4), 4 * policy::PLAN_CHUNKS_PER_THREAD);
        assert!(policy::plan_chunks(0) >= 1);
    }
}
