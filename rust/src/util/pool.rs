//! A reusable scoped thread pool for the training hot path.
//!
//! The pool hands out *borrowed* work items — each worker receives
//! `&mut I` for a disjoint item — which is exactly what block-sharded
//! optimizer updates and chunk-parallel collectives need: disjoint mutable
//! slices over the flat parameter/gradient vectors, no `Arc`, no copies.
//!
//! Implementation notes:
//!
//! * Workers are `std::thread::scope` threads, so items may borrow from the
//!   caller's stack (the flat parameter vector lives in the trainer).
//! * Scheduling is dynamic: workers pull the next item from a shared
//!   iterator, so a skewed block table (BERT's word-embedding block is ~20%
//!   of all parameters) does not serialize on a bad static partition.
//! * Results come back in item order regardless of which worker ran what —
//!   reductions that combine them stay deterministic.
//! * `threads == 1` (or fewer than two items) never spawns: that path is
//!   a plain serial loop, bit-identical to the pre-pool code.

use std::sync::Mutex;

/// Fixed-width scoped thread pool.  Cheap to construct (no persistent
/// threads); share one per trainer/executor and call [`ThreadPool::map_mut`]
/// per parallel region.
#[derive(Debug, Clone)]
pub struct ThreadPool {
    threads: usize,
}

impl ThreadPool {
    /// A pool with `threads` workers; `0` selects the machine's available
    /// parallelism.  The width is clamped to at least 1.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = if threads == 0 { Self::available() } else { threads };
        ThreadPool { threads: threads.max(1) }
    }

    /// The machine's available parallelism (1 if unknown).
    pub fn available() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Apply `f` to every item, distributing items across the pool's
    /// workers.  Results are returned in item order.  Runs serially (no
    /// threads spawned) when the pool is width-1 or there are fewer than
    /// two items.
    pub fn map_mut<I, T, F>(&self, items: &mut [I], f: F) -> Vec<T>
    where
        I: Send,
        T: Send,
        F: Fn(&mut I) -> T + Sync,
    {
        let n = items.len();
        if self.threads <= 1 || n <= 1 {
            return items.iter_mut().map(f).collect();
        }
        let workers = self.threads.min(n);
        let queue = Mutex::new(items.iter_mut().enumerate());
        let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    // take the lock only to pop the next item; `f` runs
                    // outside it
                    let next = queue.lock().unwrap().next();
                    match next {
                        Some((i, item)) => {
                            let out = f(item);
                            *slots[i].lock().unwrap() = Some(out);
                        }
                        None => break,
                    }
                });
            }
        });
        slots
            .into_iter()
            .map(|s| s.into_inner().unwrap().expect("pool worker lost a result"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_means_available() {
        assert_eq!(ThreadPool::new(0).threads(), ThreadPool::available());
        assert_eq!(ThreadPool::new(3).threads(), 3);
    }

    #[test]
    fn map_mut_matches_serial_and_preserves_order() {
        let mut a: Vec<u64> = (0..97).collect();
        let mut b = a.clone();
        let serial: Vec<u64> = ThreadPool::new(1).map_mut(&mut a, |x| {
            *x += 1;
            *x * 2
        });
        let parallel: Vec<u64> = ThreadPool::new(4).map_mut(&mut b, |x| {
            *x += 1;
            *x * 2
        });
        assert_eq!(serial, parallel);
        assert_eq!(a, b);
        assert_eq!(serial[10], 22);
    }

    #[test]
    fn mutates_disjoint_slices() {
        let mut data = vec![1.0f32; 64];
        let mut chunks: Vec<&mut [f32]> = data.chunks_mut(7).collect();
        let sums = ThreadPool::new(8).map_mut(&mut chunks, |c| {
            for x in c.iter_mut() {
                *x *= 2.0;
            }
            c.len()
        });
        assert_eq!(sums.iter().sum::<usize>(), 64);
        assert!(data.iter().all(|&x| x == 2.0));
    }

    #[test]
    fn more_threads_than_items() {
        let mut items = vec![5usize, 6];
        let out = ThreadPool::new(16).map_mut(&mut items, |x| *x * 10);
        assert_eq!(out, vec![50, 60]);
    }

    #[test]
    fn empty_items() {
        let mut items: Vec<usize> = Vec::new();
        let out: Vec<usize> = ThreadPool::new(4).map_mut(&mut items, |x| *x);
        assert!(out.is_empty());
    }
}
