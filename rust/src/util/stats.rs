//! Small statistics helpers shared by metrics, variance study and benches.

/// Online mean/variance (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Unbiased sample variance.
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Exponential moving average (loss smoothing in the trainer).
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        };
        self.value = Some(v);
        v
    }

    pub fn value(&self) -> Option<f64> {
        self.value
    }
}

/// Percentile over a copy of the data (p in [0, 100], linear interpolation).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Trapezoidal area under a uniformly-sampled curve with unit spacing —
/// used for Fig. 1's area-under-schedule numbers.
pub fn auc_unit_spacing(ys: &[f64]) -> f64 {
    if ys.len() < 2 {
        return 0.0;
    }
    let mut a = 0.0;
    for w in ys.windows(2) {
        a += 0.5 * (w[0] + w[1]);
    }
    a
}

/// Median over a copy of the data (0.0 for an empty slice).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    percentile(xs, 50.0)
}

/// Median absolute deviation around a precomputed median.
pub fn mad(xs: &[f64], med: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let devs: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    median(&devs)
}

/// Robust z-score: deviation from the median in units of
/// 1.4826·MAD (the MAD-to-σ factor for a normal distribution).  The MAD is
/// floored at `mad_floor` so near-constant windows (MAD ≈ 0) don't turn
/// measurement noise into huge z-scores.
pub fn robust_z(x: f64, med: f64, mad: f64, mad_floor: f64) -> f64 {
    let scale = 1.4826 * mad.max(mad_floor);
    if scale <= 0.0 {
        return 0.0;
    }
    (x - med) / scale
}

/// Fixed-capacity rolling window over a scalar time series (health
/// monitoring: trailing medians/MADs over step times).
#[derive(Debug, Clone)]
pub struct RollingWindow {
    cap: usize,
    buf: std::collections::VecDeque<f64>,
}

impl RollingWindow {
    pub fn new(cap: usize) -> RollingWindow {
        assert!(cap > 0, "rolling window needs capacity >= 1");
        RollingWindow { cap, buf: std::collections::VecDeque::with_capacity(cap) }
    }

    pub fn push(&mut self, x: f64) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(x);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.buf.len() == self.cap
    }

    /// Oldest-to-newest copy of the current contents.
    pub fn values(&self) -> Vec<f64> {
        self.buf.iter().copied().collect()
    }

    pub fn median(&self) -> f64 {
        median(&self.values())
    }

    pub fn mad(&self) -> f64 {
        let v = self.values();
        mad(&v, median(&v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let m = mean(&xs);
        let var = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - m).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..50 {
            e.push(10.0);
        }
        assert!((e.value().unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn median_and_mad_are_robust_to_one_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.0, 1.2, 0.8, 100.0];
        let med = median(&xs);
        assert!((med - 1.0).abs() < 1e-9, "median dragged by outlier: {med}");
        let m = mad(&xs, med);
        assert!((m - 0.1).abs() < 1e-9, "mad: {m}");
        // the outlier itself scores a huge robust z, the inliers do not
        assert!(robust_z(100.0, med, m, 1e-9) > 100.0);
        assert!(robust_z(1.2, med, m, 1e-9).abs() < 2.0);
        // empty-slice conventions
        assert_eq!(median(&[]), 0.0);
        assert_eq!(mad(&[], 0.0), 0.0);
    }

    #[test]
    fn robust_z_mad_floor_prevents_blowup() {
        // constant window: MAD = 0 — without the floor any deviation would
        // be an infinite z-score
        let xs = [1.0; 10];
        let med = median(&xs);
        let m = mad(&xs, med);
        assert_eq!(m, 0.0);
        let z = robust_z(1.01, med, m, 0.05 * med);
        assert!(z < 1.0, "noise-level deviation must stay small: {z}");
        assert_eq!(robust_z(2.0, 1.0, 0.0, 0.0), 0.0, "zero scale yields 0, not inf");
    }

    #[test]
    fn rolling_window_evicts_oldest() {
        let mut w = RollingWindow::new(3);
        assert!(w.is_empty());
        for x in [1.0, 2.0, 3.0, 4.0] {
            w.push(x);
        }
        assert!(w.is_full());
        assert_eq!(w.len(), 3);
        assert_eq!(w.values(), vec![2.0, 3.0, 4.0]);
        assert_eq!(w.median(), 3.0);
        assert_eq!(w.mad(), 1.0);
    }

    #[test]
    fn auc_linear_ramp() {
        // y = t over [0, 10]: area = 50
        let ys: Vec<f64> = (0..=10).map(|t| t as f64).collect();
        assert!((auc_unit_spacing(&ys) - 50.0).abs() < 1e-12);
    }
}
