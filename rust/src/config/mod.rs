//! Typed experiment configuration: TOML-subset files + presets.
//!
//! Everything the launcher (`lans train …`) needs lives here: which
//! artifact to load, the parallelism/batching geometry, the optimizer and
//! schedule (Table 1 presets included), and the data source.

pub mod parser;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::metrics::log::LogLevel;
use crate::optim::schedule::{from_ratios, Schedule};
use crate::optim::Hyper;
use crate::precision::{DType, DynamicLossScaler, LossScale};
use crate::topology::{TierPrecision, Topology};

pub use parser::{Document, Value};

/// Which optimizer-update implementation the trainer uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptBackend {
    /// pure-rust update (fast laptop path; bit-checked against HLO in tests)
    Native,
    /// the AOT Pallas kernel artifact via PJRT
    Hlo,
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub meta_path: PathBuf,
    pub optimizer: String,
    pub backend: OptBackend,
    pub workers: usize,
    /// width of the optimizer/allreduce thread pool: `0` = auto (the
    /// machine's available parallelism), `1` = the exact serial legacy path
    pub threads: usize,
    /// ZeRO-1-style sharded optimizer (native backend, lans|lamb only):
    /// reduce-scatter gradients, update only the owned shard with
    /// partitioned moments, all-gather parameters.  Bit-identical to the
    /// replicated path; cuts per-worker update compute and moment memory
    /// by the worker count.
    pub shard_optimizer: bool,
    /// with `shard_optimizer` + `resume_from`: also restore the per-shard
    /// optimizer moments embedded in the checkpoint (resharded to the
    /// current worker count) instead of the default moment restart — the
    /// exact-continuation path, as opposed to the two-phase warm start
    pub resume_opt_state: bool,
    /// the declared cluster shape (`"flat"` or `"<nodes>x<gpus_per_node>"`,
    /// world must equal `workers`): tiers the ring's hops into intra-node
    /// and inter-node links, splitting wire-byte accounting per tier and
    /// letting `grad_dtype`/`intra_dtype` quantize each tier separately.
    /// The fp32 trajectory is exact-bit identical for every topology (the
    /// tiered ring keeps the flat ring's reduction order — DESIGN.md §8)
    pub topology: Topology,
    /// gradient *wire* format on the scarce inter-node tier (every hop of
    /// a `flat` topology; native backend): `f32` is the historical exact
    /// path; `f16`/`bf16` quantize each hop's chunk at the wire boundary
    /// while accumulating in f32 — master params and moments stay f32
    /// regardless (the paper's fp32-master mixed-precision run)
    pub grad_dtype: DType,
    /// wire format of the plentiful intra-node (NVLink-class) hops of a
    /// hierarchical topology: `f32` (default, the paper's config) or equal
    /// to `grad_dtype` — a gathered value crosses both tiers, so a second
    /// distinct half format would break replica bit-identity (validated)
    pub intra_dtype: DType,
    /// loss scaling (native backend): `off`, a fixed power-of-two, or
    /// dynamic (backoff on overflow, growth after a quiet interval);
    /// overflowed steps are skipped and logged by the Recorder
    pub loss_scale: LossScale,
    /// bucketed gradient pipeline (native backend): cut the flat gradient
    /// into ~`bucket_mb` MiB buckets on the shard plan's `NORM_SEG` grid
    /// and run the step as a comm/compute DAG — communicate bucket `k`
    /// while digesting bucket `k-1`.  `0` (default) keeps the
    /// phase-synchronous step.  Exact-bit identical either way
    /// (DESIGN.md §9)
    pub bucket_mb: usize,
    /// with `bucket_mb > 0`: execute the step DAG on the thread pool so
    /// comm and compute stages actually overlap (`false` runs the same
    /// DAG serially in declaration order — the reference schedule, useful
    /// for debugging; results are bit-identical)
    pub overlap: bool,
    /// replicated path only: swap the tiered ring allreduce for the
    /// leader-based hierarchical allreduce (`leader_allreduce`) that the
    /// `cost::hierarchical_allreduce_shard_aware_time_s` model prices.
    /// Fewer scarce inter-node hops, but a *different* f32 summation
    /// order — the trajectory is no longer bit-identical to the flat-ring
    /// baseline, hence the explicit opt-in (DESIGN.md §9)
    pub relaxed_collectives: bool,
    /// per-worker microbatch must equal the artifact's static batch dim
    pub global_batch: usize,
    pub steps: u64,
    pub seed: u64,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub hyper: Hyper,
    pub schedule: Schedule,
    pub data: DataConfig,
    pub checkpoint: Option<PathBuf>,
    /// warm-start parameters from a checkpoint (optimizer moments restart,
    /// as in the reference two-phase BERT implementations)
    pub resume_from: Option<PathBuf>,
    pub curve_out: Option<PathBuf>,
    /// write a Chrome-trace/Perfetto JSON span timeline of the run here
    /// (open in `chrome://tracing` or `ui.perfetto.dev`): one lane per
    /// pool worker plus the coordinator lane, per-step `comm`/`compute`/
    /// stage spans, and wire-byte counters.  Also switches on the
    /// per-step `comm_s`/`compute_s`/`overlap_eff` Recorder TSV columns.
    /// `None` (default) keeps tracing compiled out of the hot path — one
    /// relaxed atomic load per instrumented seam (DESIGN.md §10)
    pub trace: Option<PathBuf>,
    /// stop as soon as the EMA loss exceeds ceiling×initial (divergence)
    pub stop_on_divergence: bool,
    /// run-health telemetry knobs (`[metrics]` section, DESIGN.md §12)
    pub metrics: MetricsConfig,
    /// flight-recorder / postmortem knobs (`[flight]` section, DESIGN.md §13)
    pub flight: FlightConfig,
    /// chaos knob: synthesize a worker failure at `step@worker` (e.g.
    /// `"20@5"` kills worker 5's step-20 reply).  The run fails exactly as
    /// a real mid-step death would — and, with the flight recorder armed,
    /// seals a postmortem bundle naming the injected lane.  `None`
    /// (default) injects nothing
    pub inject_failure: Option<FailurePoint>,
}

/// Flight-recorder knobs (`[flight]` section).  All off by default — the
/// recorder then costs one relaxed atomic load per seam and the trainer's
/// output is bit-identical to a build without the subsystem (DESIGN.md §13).
#[derive(Debug, Clone)]
pub struct FlightConfig {
    /// retain the last-K-steps ring without necessarily sealing to disk
    pub enabled: bool,
    /// ring capacity K: how many trailing steps of frames to retain
    pub steps: usize,
    /// seal a `lans-postmortem-v1` bundle here on the first trigger (Warn
    /// health verdict, skip burst, worker failure, pool poison); setting
    /// this arms the recorder
    pub bundle: Option<PathBuf>,
}

impl Default for FlightConfig {
    fn default() -> FlightConfig {
        FlightConfig { enabled: false, steps: 32, bundle: None }
    }
}

impl FlightConfig {
    /// Whether the trainer should arm the flight recorder.
    pub fn active(&self) -> bool {
        self.enabled || self.bundle.is_some()
    }
}

/// A single injected worker failure: worker `worker` dies at step `step`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FailurePoint {
    pub step: u64,
    pub worker: usize,
}

impl FailurePoint {
    /// Parse the `"step@worker"` config form.
    pub fn parse(s: &str) -> Option<FailurePoint> {
        let (step, worker) = s.split_once('@')?;
        Some(FailurePoint {
            step: step.trim().parse().ok()?,
            worker: worker.trim().parse().ok()?,
        })
    }
}

/// Run-telemetry knobs (`[metrics]` section).  All off by default — the
/// registry then costs one relaxed atomic load per seam and the trainer's
/// output is bit-identical to a build without the subsystem.
#[derive(Debug, Clone)]
pub struct MetricsConfig {
    /// write the per-step JSONL time-series here (enables the registry)
    pub jsonl: Option<PathBuf>,
    /// write the end-of-run `lans-metrics-report-v1` JSON here (enables
    /// the registry)
    pub report: Option<PathBuf>,
    /// turn the registry + health monitor on without writing files — the
    /// in-memory report still lands on `TrainReport::metrics`
    pub enabled: bool,
    /// rolling-window length (steps) for the health monitor's robust
    /// statistics
    pub window: usize,
    /// diagnostic verbosity of the trainer's leveled log sink
    pub log_level: LogLevel,
    /// caller-supplied `cluster::timemodel` step-time prediction (seconds);
    /// the report prints measured-vs-model deltas when set
    pub model_step_time_s: Option<f64>,
}

impl Default for MetricsConfig {
    fn default() -> MetricsConfig {
        MetricsConfig {
            jsonl: None,
            report: None,
            enabled: false,
            window: 32,
            log_level: LogLevel::Normal,
            model_step_time_s: None,
        }
    }
}

impl MetricsConfig {
    /// Whether the trainer should switch the registry/health monitor on.
    pub fn active(&self) -> bool {
        self.enabled || self.jsonl.is_some() || self.report.is_some()
    }
}

#[derive(Debug, Clone)]
pub struct DataConfig {
    /// "synthetic" (Markov-Zipf) or "text" (embedded corpus)
    pub source: String,
    pub vocab: usize,
    pub corpus_tokens: usize,
    pub seed: u64,
}

impl TrainConfig {
    /// Parse from a TOML-subset file.
    pub fn from_file(path: &Path) -> Result<TrainConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = Document::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Self::from_doc(&doc, path.parent().unwrap_or(Path::new(".")))
    }

    pub fn from_doc(doc: &Document, base: &Path) -> Result<TrainConfig> {
        let meta = doc
            .get("model", "meta")
            .and_then(Value::as_str)
            .context("config needs [model] meta = \"<path>\"")?;
        let meta_path = base.join(meta);

        let backend = match doc.str_or("train", "backend", "native") {
            "native" => OptBackend::Native,
            "hlo" => OptBackend::Hlo,
            other => bail!("unknown backend {other:?} (native|hlo)"),
        };

        let hyper = Hyper {
            beta1: doc.f64_or("optimizer", "beta1", 0.9) as f32,
            beta2: doc.f64_or("optimizer", "beta2", 0.999) as f32,
            eps: doc.f64_or("optimizer", "eps", 1e-6) as f32,
            weight_decay: doc.f64_or("optimizer", "weight_decay", 0.01) as f32,
        };

        let grad_dtype_s = doc.str_or("train", "grad_dtype", "f32");
        let grad_dtype = DType::parse(grad_dtype_s).ok_or_else(|| {
            anyhow::anyhow!("unknown grad_dtype {grad_dtype_s:?} (f32|f16|bf16)")
        })?;
        let intra_dtype_s = doc.str_or("train", "intra_dtype", "f32");
        let intra_dtype = DType::parse(intra_dtype_s).ok_or_else(|| {
            anyhow::anyhow!("unknown intra_dtype {intra_dtype_s:?} (f32|f16|bf16)")
        })?;
        // one home for the tier-compatibility rule (the trainer re-checks
        // it for programmatically built configs)
        if let Err(e) = (TierPrecision { intra: intra_dtype, inter: grad_dtype }).validate() {
            bail!("bad intra_dtype/grad_dtype combination: {e}");
        }
        let workers = doc.usize_or("train", "workers", 2);
        let topo_s = doc.str_or("train", "topology", "flat");
        let topology = Topology::parse(topo_s, workers).map_err(|e| {
            anyhow::anyhow!(
                "bad topology {topo_s:?} (expect \"flat\" or \"<nodes>x<gpus_per_node>\" \
                 matching workers = {workers}): {e}"
            )
        })?;
        let loss_scale = match doc.get("train", "loss_scale") {
            None => LossScale::Off,
            Some(Value::Str(s)) => match s.as_str() {
                "off" | "none" => LossScale::Off,
                "dynamic" => LossScale::Dynamic { init: DynamicLossScaler::DEFAULT_INIT },
                other => bail!(
                    "unknown loss_scale {other:?} (off|dynamic|<positive number>)"
                ),
            },
            Some(v) => match v.as_f64() {
                // validate here so a bad value is a contextual config
                // error, not a panic when the scaler is built at run start
                Some(x)
                    if (x as f32).is_finite()
                        && (x as f32) >= DynamicLossScaler::MIN_SCALE
                        && (x as f32) <= DynamicLossScaler::MAX_SCALE =>
                {
                    LossScale::Static(x as f32)
                }
                _ => bail!(
                    "loss_scale must be \"off\", \"dynamic\" or a number in \
                     [{:e}, {:e}] (rounded to the nearest power of two), \
                     got {v:?}",
                    DynamicLossScaler::MIN_SCALE,
                    DynamicLossScaler::MAX_SCALE
                ),
            },
        };

        let steps = doc.usize_or("train", "steps", 100) as u64;
        let eta = doc.f64_or("schedule", "eta", 0.00675);
        let schedule = match doc.str_or("schedule", "kind", "warmup_const_decay") {
            "constant" => Schedule::Constant { eta },
            "linear_warmup_decay" => Schedule::LinearWarmupDecay {
                eta,
                t_warmup: doc.usize_or("schedule", "warmup", (steps / 10) as usize) as u64,
                t_total: steps,
            },
            "warmup_const_decay" => from_ratios(
                eta,
                steps,
                doc.f64_or("schedule", "ratio_warmup", 0.4265),
                doc.f64_or("schedule", "ratio_const", 0.2735),
            ),
            other => bail!("unknown schedule kind {other:?}"),
        };

        let log_level_s = doc.str_or("metrics", "log_level", "normal");
        let log_level = LogLevel::parse(log_level_s).ok_or_else(|| {
            anyhow::anyhow!("unknown log_level {log_level_s:?} (quiet|normal|verbose)")
        })?;
        let model_step_time_s = match doc.get("metrics", "model_step_time_s") {
            None => None,
            Some(v) => match v.as_f64() {
                Some(x) if x.is_finite() && x > 0.0 => Some(x),
                _ => bail!("model_step_time_s must be a positive number, got {v:?}"),
            },
        };
        let metrics = MetricsConfig {
            jsonl: doc.get("metrics", "jsonl").and_then(Value::as_str).map(|s| base.join(s)),
            report: doc
                .get("metrics", "report")
                .and_then(Value::as_str)
                .map(|s| base.join(s)),
            enabled: doc.bool_or("metrics", "enabled", false),
            window: doc.usize_or("metrics", "window", 32).max(4),
            log_level,
            model_step_time_s,
        };

        let flight = FlightConfig {
            enabled: doc.bool_or("flight", "enabled", false),
            steps: doc.usize_or("flight", "steps", 32).max(2),
            bundle: doc
                .get("flight", "bundle")
                .and_then(Value::as_str)
                .map(|s| base.join(s)),
        };
        let inject_failure = match doc.get("train", "inject_failure") {
            None => None,
            Some(v) => {
                let s = v.as_str().unwrap_or_default();
                Some(FailurePoint::parse(s).ok_or_else(|| {
                    anyhow::anyhow!(
                        "inject_failure must be \"<step>@<worker>\" (e.g. \"20@5\"), got {v:?}"
                    )
                })?)
            }
        };

        Ok(TrainConfig {
            meta_path,
            optimizer: doc.str_or("train", "optimizer", "lans").to_string(),
            backend,
            workers,
            threads: doc.usize_or("train", "threads", 0),
            shard_optimizer: doc.bool_or("train", "shard_optimizer", false),
            resume_opt_state: doc.bool_or("train", "resume_opt_state", false),
            topology,
            grad_dtype,
            intra_dtype,
            loss_scale,
            bucket_mb: doc.usize_or("train", "bucket_mb", 0),
            overlap: doc.bool_or("train", "overlap", true),
            relaxed_collectives: doc.bool_or("train", "relaxed_collectives", false),
            global_batch: doc.usize_or("train", "global_batch", 16),
            steps,
            seed: doc.usize_or("train", "seed", 42) as u64,
            eval_every: doc.usize_or("train", "eval_every", 0) as u64,
            eval_batches: doc.usize_or("train", "eval_batches", 4),
            hyper,
            schedule,
            data: DataConfig {
                source: doc.str_or("data", "source", "synthetic").to_string(),
                vocab: doc.usize_or("data", "vocab", 2048),
                corpus_tokens: doc.usize_or("data", "corpus_tokens", 262144),
                seed: doc.usize_or("data", "seed", 7) as u64,
            },
            checkpoint: doc
                .get("train", "checkpoint")
                .and_then(Value::as_str)
                .map(|s| base.join(s)),
            resume_from: doc
                .get("train", "resume_from")
                .and_then(Value::as_str)
                .map(|s| base.join(s)),
            curve_out: doc
                .get("train", "curve_out")
                .and_then(Value::as_str)
                .map(|s| base.join(s)),
            trace: doc
                .get("train", "trace")
                .and_then(Value::as_str)
                .map(|s| base.join(s)),
            stop_on_divergence: doc.bool_or("train", "stop_on_divergence", true),
            metrics,
            flight,
            inject_failure,
        })
    }

    /// Table 1 stage-1 preset, rescaled to `steps` at laptop scale.
    pub fn paper_stage1_schedule(eta: f64, steps: u64) -> Schedule {
        from_ratios(eta, steps, 0.4265, 0.2735)
    }

    /// Table 1 stage-2 preset (warmup 19.2%, const 10.8%).
    pub fn paper_stage2_schedule(eta: f64, steps: u64) -> Schedule {
        from_ratios(eta, steps, 0.192, 0.108)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_config_parses() {
        let doc = Document::parse(
            r#"
            [model]
            meta = "artifacts/bert-tiny_s64_b4.meta.json"
            [train]
            optimizer = "lamb"
            backend = "hlo"
            workers = 4
            threads = 8
            shard_optimizer = true
            global_batch = 64
            steps = 500
            [schedule]
            kind = "warmup_const_decay"
            eta = 0.00675
            [data]
            source = "text"
            "#,
        )
        .unwrap();
        let c = TrainConfig::from_doc(&doc, Path::new("/base")).unwrap();
        assert_eq!(c.optimizer, "lamb");
        assert_eq!(c.backend, OptBackend::Hlo);
        assert_eq!(c.workers, 4);
        assert_eq!(c.threads, 8);
        assert!(c.shard_optimizer);
        assert!(!c.resume_opt_state);
        // precision + topology knobs default to the historical exact path
        assert_eq!(c.grad_dtype, DType::F32);
        assert_eq!(c.intra_dtype, DType::F32);
        assert_eq!(c.loss_scale, LossScale::Off);
        assert_eq!(c.topology, Topology::flat(4));
        // pipeline knobs: bucketing off, overlap armed for when it's on
        assert_eq!(c.bucket_mb, 0);
        assert!(c.overlap);
        assert!(!c.relaxed_collectives);
        assert!(c.meta_path.starts_with("/base"));
        assert_eq!(c.data.source, "text");
        match c.schedule {
            Schedule::WarmupConstDecay { t_warmup, t_const, t_total, .. } => {
                assert_eq!(t_total, 500);
                // 70% of steps in warmup+const (Table 1 stage-1 constraint)
                assert!((t_warmup + t_const) as f64 / 500.0 - 0.70 < 0.01);
            }
            _ => panic!("wrong schedule"),
        }
    }

    #[test]
    fn missing_meta_is_error() {
        let doc = Document::parse("[train]\nsteps = 5").unwrap();
        assert!(TrainConfig::from_doc(&doc, Path::new(".")).is_err());
    }

    #[test]
    fn bad_backend_is_error() {
        let doc = Document::parse(
            "[model]\nmeta = \"m.json\"\n[train]\nbackend = \"gpu\"",
        )
        .unwrap();
        assert!(TrainConfig::from_doc(&doc, Path::new(".")).is_err());
    }

    #[test]
    fn precision_knobs_parse() {
        let doc = Document::parse(
            "[model]\nmeta = \"m.json\"\n[train]\ngrad_dtype = \"f16\"\n\
             loss_scale = \"dynamic\"",
        )
        .unwrap();
        let c = TrainConfig::from_doc(&doc, Path::new(".")).unwrap();
        assert_eq!(c.grad_dtype, DType::F16);
        assert_eq!(
            c.loss_scale,
            LossScale::Dynamic { init: DynamicLossScaler::DEFAULT_INIT }
        );

        let doc = Document::parse(
            "[model]\nmeta = \"m.json\"\n[train]\ngrad_dtype = \"bf16\"\n\
             loss_scale = 1024",
        )
        .unwrap();
        let c = TrainConfig::from_doc(&doc, Path::new(".")).unwrap();
        assert_eq!(c.grad_dtype, DType::Bf16);
        assert_eq!(c.loss_scale, LossScale::Static(1024.0));

        let doc = Document::parse(
            "[model]\nmeta = \"m.json\"\n[train]\nloss_scale = \"off\"",
        )
        .unwrap();
        assert_eq!(
            TrainConfig::from_doc(&doc, Path::new(".")).unwrap().loss_scale,
            LossScale::Off
        );
    }

    #[test]
    fn pipeline_knobs_parse() {
        let doc = Document::parse(
            "[model]\nmeta = \"m.json\"\n[train]\nbucket_mb = 4\noverlap = false",
        )
        .unwrap();
        let c = TrainConfig::from_doc(&doc, Path::new(".")).unwrap();
        assert_eq!(c.bucket_mb, 4);
        assert!(!c.overlap);

        let doc = Document::parse(
            "[model]\nmeta = \"m.json\"\n[train]\nrelaxed_collectives = true",
        )
        .unwrap();
        assert!(TrainConfig::from_doc(&doc, Path::new(".")).unwrap().relaxed_collectives);
    }

    #[test]
    fn trace_knob_parses_like_curve_out() {
        let doc = Document::parse(
            "[model]\nmeta = \"m.json\"\n[train]\ntrace = \"out/trace.json\"",
        )
        .unwrap();
        let c = TrainConfig::from_doc(&doc, Path::new("/base")).unwrap();
        assert_eq!(c.trace.as_deref(), Some(Path::new("/base/out/trace.json")));

        // default: off — the no-overhead contract path
        let doc = Document::parse("[model]\nmeta = \"m.json\"").unwrap();
        assert_eq!(TrainConfig::from_doc(&doc, Path::new(".")).unwrap().trace, None);
    }

    #[test]
    fn metrics_knobs_parse() {
        let doc = Document::parse(
            "[model]\nmeta = \"m.json\"\n[metrics]\njsonl = \"out/run.jsonl\"\n\
             report = \"out/report.json\"\nwindow = 16\nlog_level = \"verbose\"\n\
             model_step_time_s = 0.0125",
        )
        .unwrap();
        let c = TrainConfig::from_doc(&doc, Path::new("/base")).unwrap();
        assert_eq!(c.metrics.jsonl.as_deref(), Some(Path::new("/base/out/run.jsonl")));
        assert_eq!(c.metrics.report.as_deref(), Some(Path::new("/base/out/report.json")));
        assert_eq!(c.metrics.window, 16);
        assert_eq!(c.metrics.log_level, LogLevel::Verbose);
        assert_eq!(c.metrics.model_step_time_s, Some(0.0125));
        assert!(c.metrics.active());

        // default: everything off — the no-overhead contract path
        let doc = Document::parse("[model]\nmeta = \"m.json\"").unwrap();
        let c = TrainConfig::from_doc(&doc, Path::new(".")).unwrap();
        assert!(!c.metrics.active());
        assert_eq!(c.metrics.window, 32);
        assert_eq!(c.metrics.log_level, LogLevel::Normal);
        assert!(c.metrics.jsonl.is_none() && c.metrics.report.is_none());

        // `enabled` arms the registry without file outputs
        let doc = Document::parse(
            "[model]\nmeta = \"m.json\"\n[metrics]\nenabled = true",
        )
        .unwrap();
        assert!(TrainConfig::from_doc(&doc, Path::new(".")).unwrap().metrics.active());

        // bad knobs are contextual config errors
        for body in ["log_level = \"loud\"", "model_step_time_s = -1", "model_step_time_s = \"fast\""] {
            let doc = Document::parse(&format!(
                "[model]\nmeta = \"m.json\"\n[metrics]\n{body}"
            ))
            .unwrap();
            assert!(
                TrainConfig::from_doc(&doc, Path::new(".")).is_err(),
                "{body} should be rejected"
            );
        }
    }

    #[test]
    fn flight_knobs_parse() {
        let doc = Document::parse(
            "[model]\nmeta = \"m.json\"\n[flight]\nsteps = 8\n\
             bundle = \"out/postmortem.json\"",
        )
        .unwrap();
        let c = TrainConfig::from_doc(&doc, Path::new("/base")).unwrap();
        assert_eq!(c.flight.steps, 8);
        assert_eq!(c.flight.bundle.as_deref(), Some(Path::new("/base/out/postmortem.json")));
        assert!(c.flight.active(), "a bundle path arms the recorder");

        // default: off — the no-overhead contract path
        let doc = Document::parse("[model]\nmeta = \"m.json\"").unwrap();
        let c = TrainConfig::from_doc(&doc, Path::new(".")).unwrap();
        assert!(!c.flight.active());
        assert_eq!(c.flight.steps, 32);
        assert!(c.flight.bundle.is_none());

        // `enabled` retains the ring without a bundle file
        let doc = Document::parse(
            "[model]\nmeta = \"m.json\"\n[flight]\nenabled = true",
        )
        .unwrap();
        assert!(TrainConfig::from_doc(&doc, Path::new(".")).unwrap().flight.active());

        // the ring floor keeps a degenerate K from discarding the trigger step
        let doc = Document::parse(
            "[model]\nmeta = \"m.json\"\n[flight]\nsteps = 0",
        )
        .unwrap();
        assert_eq!(TrainConfig::from_doc(&doc, Path::new(".")).unwrap().flight.steps, 2);
    }

    #[test]
    fn inject_failure_knob_parses() {
        let doc = Document::parse(
            "[model]\nmeta = \"m.json\"\n[train]\ninject_failure = \"20@5\"",
        )
        .unwrap();
        let c = TrainConfig::from_doc(&doc, Path::new(".")).unwrap();
        assert_eq!(c.inject_failure, Some(FailurePoint { step: 20, worker: 5 }));

        let doc = Document::parse("[model]\nmeta = \"m.json\"").unwrap();
        assert_eq!(TrainConfig::from_doc(&doc, Path::new(".")).unwrap().inject_failure, None);

        for body in [
            "inject_failure = \"20\"",
            "inject_failure = \"x@y\"",
            "inject_failure = \"@3\"",
            "inject_failure = 20",
        ] {
            let doc = Document::parse(&format!(
                "[model]\nmeta = \"m.json\"\n[train]\n{body}"
            ))
            .unwrap();
            assert!(
                TrainConfig::from_doc(&doc, Path::new(".")).is_err(),
                "{body} should be rejected"
            );
        }
        assert_eq!(FailurePoint::parse(" 7 @ 2 "), Some(FailurePoint { step: 7, worker: 2 }));
    }

    #[test]
    fn topology_knobs_parse() {
        let doc = Document::parse(
            "[model]\nmeta = \"m.json\"\n[train]\nworkers = 8\n\
             topology = \"2x4\"\ngrad_dtype = \"bf16\"",
        )
        .unwrap();
        let c = TrainConfig::from_doc(&doc, Path::new(".")).unwrap();
        assert_eq!(c.topology, Topology::grid(2, 4));
        assert_eq!(c.grad_dtype, DType::Bf16);
        assert_eq!(c.intra_dtype, DType::F32);

        // uniform half tiers are allowed when the formats match
        let doc = Document::parse(
            "[model]\nmeta = \"m.json\"\n[train]\nworkers = 4\n\
             topology = \"2x2\"\ngrad_dtype = \"f16\"\nintra_dtype = \"f16\"",
        )
        .unwrap();
        let c = TrainConfig::from_doc(&doc, Path::new(".")).unwrap();
        assert_eq!(c.intra_dtype, DType::F16);
    }

    #[test]
    fn bad_topology_knobs_are_errors() {
        for (body, needle) in [
            // world mismatch: 2x2 = 4 ranks, workers = 8
            ("workers = 8\ntopology = \"2x2\"", "workers"),
            ("topology = \"0x2\"", "topology"),
            ("topology = \"banana\"", "topology"),
            // a half intra tier must match the inter tier
            ("intra_dtype = \"f16\"\ngrad_dtype = \"bf16\"", "intra_dtype"),
            ("intra_dtype = \"bf16\"", "intra_dtype"),
            ("intra_dtype = \"int8\"", "intra_dtype"),
        ] {
            let doc = Document::parse(&format!(
                "[model]\nmeta = \"m.json\"\n[train]\n{body}"
            ))
            .unwrap();
            let err = TrainConfig::from_doc(&doc, Path::new("."))
                .expect_err(&format!("{body} should be rejected"));
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "{body}: unhelpful error {msg}");
        }
    }

    #[test]
    fn bad_precision_knobs_are_errors() {
        for body in [
            "grad_dtype = \"int8\"",
            "loss_scale = \"huge\"",
            "loss_scale = -4",
            "loss_scale = 0",
            // overflows f32 to inf / underflows to 0: must be a config
            // error, not a panic at run start
            "loss_scale = 4e38",
            "loss_scale = 1e-46",
        ] {
            let doc = Document::parse(&format!(
                "[model]\nmeta = \"m.json\"\n[train]\n{body}"
            ))
            .unwrap();
            assert!(
                TrainConfig::from_doc(&doc, Path::new(".")).is_err(),
                "{body} should be rejected"
            );
        }
    }
}
