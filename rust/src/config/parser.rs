//! TOML-subset parser — the config-file substrate (the `toml` crate is
//! unavailable offline).
//!
//! Supported grammar (covers everything the launcher needs):
//!   [section] headers, `key = value` pairs, `#` comments,
//!   values: quoted strings, booleans, integers, floats.
//! Unsupported on purpose: arrays-of-tables, inline tables, multi-line
//! strings, datetimes.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Int(x) => write!(f, "{x}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct Document {
    /// section → key → value ("" is the root section)
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Document {
    pub fn parse(text: &str) -> Result<Document, ParseError> {
        let mut doc = Document::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| ParseError { line: lineno + 1, msg: msg.to_string() };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated section header"))?
                    .trim();
                if name.is_empty() {
                    return Err(err("empty section name"));
                }
                section = name.to_string();
                doc.sections.entry(section.clone()).or_default();
            } else if let Some(eq) = line.find('=') {
                let key = line[..eq].trim();
                let val = line[eq + 1..].trim();
                if key.is_empty() {
                    return Err(err("empty key"));
                }
                let value = parse_value(val).map_err(|m| err(&m))?;
                doc.sections
                    .entry(section.clone())
                    .or_default()
                    .insert(key.to_string(), value);
            } else {
                return Err(err("expected `[section]` or `key = value`"));
            }
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key).and_then(Value::as_usize).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        if inner.contains('"') {
            return Err("embedded quote in string".to_string());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Document::parse(
            r#"
            top = 1
            [train]           # trailing comment
            optimizer = "lans"
            workers = 4
            eta = 6.75e-3
            resume = false
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "top"), Some(&Value::Int(1)));
        assert_eq!(doc.str_or("train", "optimizer", "x"), "lans");
        assert_eq!(doc.usize_or("train", "workers", 0), 4);
        assert!((doc.f64_or("train", "eta", 0.0) - 0.00675).abs() < 1e-12);
        assert!(!doc.bool_or("train", "resume", true));
    }

    #[test]
    fn defaults_apply() {
        let doc = Document::parse("[a]\n").unwrap();
        assert_eq!(doc.usize_or("a", "missing", 7), 7);
        assert_eq!(doc.str_or("nosection", "k", "d"), "d");
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let doc = Document::parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.get("", "k"), Some(&Value::Str("a#b".into())));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = Document::parse("[ok]\nbad line").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(Document::parse("[unterminated").is_err());
        assert!(Document::parse("k = ").is_err());
    }
}
