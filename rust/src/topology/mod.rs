//! Cluster-topology descriptor: the two-tier fabric the paper trains on
//! (192 × P3dn nodes: NVLink inside a node, one 100 Gb/s EFA NIC per node)
//! and the types the executed two-level collectives
//! (`collective::hierarchical`) are parameterized over.
//!
//! A [`Topology`] is `nodes × gpus_per_node` with the node-contiguous rank
//! layout `rank = node · gpus_per_node + local`.  Under that layout the
//! ring's hop `r → (r+1) % W` stays inside a node except when it crosses a
//! node boundary (`(r+1) % gpus_per_node == 0`), so of the `W` links in the
//! cycle exactly `nodes` are inter-node — the scarce tier.  The degenerate
//! [`flat`](Topology::flat) case is `W × 1`: every hop crosses a NIC, which
//! is the node-oblivious single ring the cost model's
//! [`flat_gpu_ring_time_s`](crate::collective::cost::flat_gpu_ring_time_s)
//! baseline prices.
//!
//! [`TierPrecision`] selects the wire format per tier (the paper's config:
//! fp32 over NVLink, f16/bf16 over the NIC) and [`WireBytes`] is the
//! split intra/inter byte accounting every executed collective returns.

use std::fmt;

use crate::precision::DType;

/// Which tier of the fabric a ring hop crosses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// inside one node (NVLink-class: plentiful bandwidth)
    Intra,
    /// between nodes (NIC-class: the scarce, shared link)
    Inter,
}

/// A two-tier cluster shape: `nodes × gpus_per_node`, ranks laid out
/// node-contiguously (`rank = node · gpus_per_node + local`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    pub nodes: usize,
    pub gpus_per_node: usize,
}

impl Topology {
    /// `nodes × gpus_per_node`, both ≥ 1.
    pub fn grid(nodes: usize, gpus_per_node: usize) -> Topology {
        assert!(nodes > 0, "topology needs at least one node");
        assert!(gpus_per_node > 0, "topology needs at least one gpu per node");
        Topology { nodes, gpus_per_node }
    }

    /// The degenerate node-oblivious case: `workers × 1` — one rank per
    /// "node", every ring hop on the inter tier.  This is exactly the
    /// historical single ring (same schedule, same bits); declaring it
    /// keeps the flat path and the hierarchical path one code path.
    pub fn flat(workers: usize) -> Topology {
        Topology::grid(workers.max(1), 1)
    }

    /// Parse the config spelling: `"flat"` or `"<nodes>x<gpus_per_node>"`
    /// (e.g. `"2x4"`).  The grid must describe exactly `workers` ranks.
    pub fn parse(s: &str, workers: usize) -> Result<Topology, String> {
        if s == "flat" {
            return Ok(Topology::flat(workers));
        }
        let (n, g) = s
            .split_once('x')
            .ok_or_else(|| "expected \"flat\" or \"<nodes>x<gpus_per_node>\"".to_string())?;
        let nodes: usize = n
            .trim()
            .parse()
            .map_err(|_| format!("bad node count {n:?}"))?;
        let gpus: usize = g
            .trim()
            .parse()
            .map_err(|_| format!("bad gpus-per-node count {g:?}"))?;
        if nodes == 0 || gpus == 0 {
            return Err("topology dimensions must be at least 1".to_string());
        }
        if nodes * gpus != workers {
            return Err(format!(
                "{nodes}x{gpus} describes {} ranks but workers = {workers}",
                nodes * gpus
            ));
        }
        Ok(Topology::grid(nodes, gpus))
    }

    /// Total ranks.
    pub fn world(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    /// One rank per node — the node-oblivious single ring.
    pub fn is_flat(&self) -> bool {
        self.gpus_per_node == 1
    }

    pub fn node_of(&self, rank: usize) -> usize {
        debug_assert!(rank < self.world());
        rank / self.gpus_per_node
    }

    pub fn local_of(&self, rank: usize) -> usize {
        debug_assert!(rank < self.world());
        rank % self.gpus_per_node
    }

    pub fn rank_of(&self, node: usize, local: usize) -> usize {
        debug_assert!(node < self.nodes && local < self.gpus_per_node);
        node * self.gpus_per_node + local
    }

    /// Which tier the link `src → dst` crosses.
    pub fn hop_tier(&self, src: usize, dst: usize) -> Tier {
        if self.node_of(src) == self.node_of(dst) {
            Tier::Intra
        } else {
            Tier::Inter
        }
    }

    /// Tier of the ring hop that *ends* at `dst` (the ring only ever hops
    /// `r → (r+1) % W`, which crosses a node boundary iff `dst` is the
    /// first rank of a node and there is more than one node).
    pub fn ring_hop_tier(&self, dst: usize) -> Tier {
        if self.nodes > 1 && dst % self.gpus_per_node == 0 {
            Tier::Inter
        } else {
            Tier::Intra
        }
    }

    /// Inter-node links in the full ring cycle (`nodes`, or 0 when the
    /// whole ring lives inside one node).
    pub fn inter_links(&self) -> usize {
        if self.nodes > 1 {
            self.nodes
        } else {
            0
        }
    }

    /// Inter-node hops on the `W−1`-hop ring path that hops into every
    /// rank except `excl` — the path every chunk takes (the
    /// reduce-scatter phase excludes the chunk index, the all-gather its
    /// owner).  The one home for the node-boundary count shared by the
    /// executed collectives (`collective::hierarchical`) and the analytic
    /// byte counters (`collective::cost::tiered_ring_phase_wire_bytes`).
    pub fn inter_hops_excluding(&self, excl: usize) -> usize {
        if self.nodes <= 1 {
            return 0;
        }
        self.inter_links() - usize::from(self.ring_hop_tier(excl) == Tier::Inter)
    }
}

impl fmt::Display for Topology {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_flat() {
            write!(f, "flat({})", self.nodes)
        } else {
            write!(f, "{}x{}", self.nodes, self.gpus_per_node)
        }
    }
}

/// Per-tier wire formats: what crosses an intra-node hop and what crosses
/// an inter-node hop.  The supported combinations are `intra == inter` or
/// `intra == F32` (see [`validate`](TierPrecision::validate)): a gathered
/// value can traverse both tiers, so it must be a fixed point of every
/// wire format on its path — guaranteed when at most one distinct half
/// format is in play, not guaranteed for e.g. f16-intra/bf16-inter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierPrecision {
    pub intra: DType,
    pub inter: DType,
}

impl TierPrecision {
    /// Both tiers exact fp32 — the historical wire.
    pub fn fp32() -> TierPrecision {
        TierPrecision { intra: DType::F32, inter: DType::F32 }
    }

    /// The same format on both tiers (what the flat half collectives do).
    pub fn uniform(d: DType) -> TierPrecision {
        TierPrecision { intra: d, inter: d }
    }

    /// The paper's two-tier config: exact fp32 over NVLink, a half format
    /// over the scarce NIC.
    pub fn half_inter(inter: DType) -> TierPrecision {
        TierPrecision { intra: DType::F32, inter }
    }

    pub fn tier(&self, t: Tier) -> DType {
        match t {
            Tier::Intra => self.intra,
            Tier::Inter => self.inter,
        }
    }

    pub fn any_half(&self) -> bool {
        self.intra.is_half() || self.inter.is_half()
    }

    /// Reject tier combinations whose replicas could disagree (a half
    /// intra format different from the inter format: a value quantized for
    /// one tier is not a fixed point of the other).
    pub fn validate(&self) -> Result<(), String> {
        if self.intra.is_half() && self.intra != self.inter {
            return Err(format!(
                "intra tier {} must be f32 or match the inter tier {}",
                self.intra.name(),
                self.inter.name()
            ));
        }
        Ok(())
    }
}

/// Wire bytes split by tier — what the executed hierarchical collectives
/// report and the analytic `collective::cost` counters predict.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireBytes {
    pub intra: u64,
    pub inter: u64,
}

impl WireBytes {
    pub fn total(&self) -> u64 {
        self.intra + self.inter
    }

    pub fn add(&mut self, tier: Tier, bytes: u64) {
        match tier {
            Tier::Intra => self.intra += bytes,
            Tier::Inter => self.inter += bytes,
        }
    }
}

impl std::ops::AddAssign for WireBytes {
    fn add_assign(&mut self, rhs: WireBytes) {
        self.intra += rhs.intra;
        self.inter += rhs.inter;
    }
}

impl std::ops::Add for WireBytes {
    type Output = WireBytes;

    fn add(mut self, rhs: WireBytes) -> WireBytes {
        self += rhs;
        self
    }
}

/// Per-tier link parameters (α-β) for modeling a declared topology —
/// defaults match the paper's P3dn testbed (NVLink intra, EFA inter).
#[derive(Debug, Clone, Copy)]
pub struct TierLinks {
    pub intra: crate::collective::cost::CommSpec,
    pub inter: crate::collective::cost::CommSpec,
}

impl Default for TierLinks {
    fn default() -> TierLinks {
        TierLinks {
            intra: crate::collective::cost::CommSpec::nvlink(),
            inter: crate::collective::cost::CommSpec::efa(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_mapping_roundtrips() {
        let t = Topology::grid(3, 4);
        assert_eq!(t.world(), 12);
        for rank in 0..t.world() {
            let (n, l) = (t.node_of(rank), t.local_of(rank));
            assert!(n < 3 && l < 4);
            assert_eq!(t.rank_of(n, l), rank);
        }
        // node-contiguous layout
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(3), 0);
        assert_eq!(t.node_of(4), 1);
        assert_eq!(t.local_of(7), 3);
    }

    #[test]
    fn ring_hops_cross_exactly_once_per_node() {
        for (nodes, gpus) in [(1, 1), (1, 8), (2, 4), (4, 2), (8, 1), (3, 5)] {
            let t = Topology::grid(nodes, gpus);
            let w = t.world();
            let crossings = (0..w)
                .filter(|&r| t.ring_hop_tier((r + 1) % w) == Tier::Inter)
                .count();
            assert_eq!(crossings, t.inter_links(), "{t}");
            // ring_hop_tier agrees with the general hop_tier on ring hops
            for r in 0..w {
                let dst = (r + 1) % w;
                assert_eq!(t.hop_tier(r, dst), t.ring_hop_tier(dst), "{t} hop {r}->{dst}");
            }
        }
    }

    #[test]
    fn inter_hops_excluding_matches_the_per_hop_count() {
        // the helper must agree with literally walking the path: hops end
        // at every rank except `excl`
        for (nodes, gpus) in [(1, 1), (1, 4), (2, 2), (2, 4), (4, 2), (3, 5), (8, 1)] {
            let t = Topology::grid(nodes, gpus);
            let w = t.world();
            for excl in 0..w {
                let walked = (0..w)
                    .filter(|&dst| dst != excl && t.ring_hop_tier(dst) == Tier::Inter)
                    .count();
                assert_eq!(t.inter_hops_excluding(excl), walked, "{t} excl={excl}");
            }
        }
    }

    #[test]
    fn flat_is_all_inter_single_node_all_intra() {
        let flat = Topology::flat(6);
        assert!(flat.is_flat());
        assert_eq!(flat.world(), 6);
        for r in 0..6 {
            assert_eq!(flat.ring_hop_tier(r), Tier::Inter);
        }
        let one = Topology::grid(1, 6);
        for r in 0..6 {
            assert_eq!(one.ring_hop_tier(r), Tier::Intra);
        }
    }

    #[test]
    fn parse_accepts_flat_and_grids() {
        assert_eq!(Topology::parse("flat", 8).unwrap(), Topology::flat(8));
        assert_eq!(Topology::parse("2x4", 8).unwrap(), Topology::grid(2, 4));
        assert_eq!(Topology::parse("8x1", 8).unwrap(), Topology::flat(8));
        assert_eq!(Topology::parse("1x1", 1).unwrap(), Topology::grid(1, 1));
    }

    #[test]
    fn parse_rejects_bad_specs() {
        for (s, w) in [
            ("2x3", 8),   // world mismatch
            ("0x4", 0),   // zero dimension
            ("4x0", 0),
            ("abc", 4),   // no separator
            ("2xtwo", 4), // non-numeric
            ("", 4),
        ] {
            let e = Topology::parse(s, w).unwrap_err();
            assert!(!e.is_empty(), "{s:?} produced an empty error");
        }
        // the mismatch error names both counts
        let e = Topology::parse("2x3", 8).unwrap_err();
        assert!(e.contains('6') && e.contains('8'), "unhelpful: {e}");
    }

    #[test]
    fn tier_precision_validation() {
        assert!(TierPrecision::fp32().validate().is_ok());
        assert!(TierPrecision::half_inter(DType::F16).validate().is_ok());
        assert!(TierPrecision::uniform(DType::Bf16).validate().is_ok());
        let bad = TierPrecision { intra: DType::F16, inter: DType::Bf16 };
        assert!(bad.validate().is_err());
        let bad = TierPrecision { intra: DType::F16, inter: DType::F32 };
        assert!(bad.validate().is_err());
        assert!(!TierPrecision::fp32().any_half());
        assert!(TierPrecision::half_inter(DType::Bf16).any_half());
    }

    #[test]
    fn wire_bytes_accumulate() {
        let mut w = WireBytes::default();
        w.add(Tier::Intra, 10);
        w.add(Tier::Inter, 3);
        w += WireBytes { intra: 5, inter: 7 };
        assert_eq!(w, WireBytes { intra: 15, inter: 10 });
        assert_eq!(w.total(), 25);
        assert_eq!(
            WireBytes { intra: 1, inter: 2 } + WireBytes { intra: 3, inter: 4 },
            WireBytes { intra: 4, inter: 6 }
        );
    }
}
