//! Minimal in-tree stand-in for the `xla` crate (PJRT / HLO bindings).
//!
//! The real crate links the XLA C++ runtime, which cannot be vendored into
//! this repository, so the workspace ships this stub with the exact API
//! surface `lans::runtime` uses:
//!
//! * [`Literal`] is fully functional (host tensors, reshape, typed
//!   readback, tuples) — the tensor round-trip tests exercise it for real.
//! * [`HloModuleProto::from_text_file`] reads and shallow-validates HLO
//!   text, so malformed artifacts fail at load time with a clear message.
//! * [`PjRtLoadedExecutable::execute`] returns an error: the stub cannot
//!   run HLO.  Artifact-gated tests and benches skip when artifacts are
//!   absent; swapping this path dependency for the real `xla` crate
//!   restores execution (see DESIGN.md §Runtime).

use std::fmt;

/// Error type mirroring `xla::Error` (Display + std::error::Error so it
/// converts into `anyhow::Error` via `?`).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn msg(s: impl Into<String>) -> Error {
        Error(s.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// XLA element types (only F32/S32 are storable in the stub; the rest exist
/// so shape-matching code has realistic non-exhaustive matches).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
    Bf16,
    F16,
    F32,
    F64,
}

/// Array shape: dimensions + element type.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

#[derive(Debug, Clone, PartialEq)]
enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host-side literal: the currency between the coordinator and PJRT.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    storage: Storage,
}

/// Host element types the stub stores natively.
pub trait NativeType: Copy + Sized {
    const TY: ElementType;
    fn store(data: Vec<Self>) -> Storage;
    fn read(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;

    fn store(data: Vec<Self>) -> Storage {
        Storage::F32(data)
    }

    fn read(lit: &Literal) -> Result<Vec<f32>> {
        match &lit.storage {
            Storage::F32(v) => Ok(v.clone()),
            _ => Err(Error::msg("literal is not f32")),
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;

    fn store(data: Vec<Self>) -> Storage {
        Storage::I32(data)
    }

    fn read(lit: &Literal) -> Result<Vec<i32>> {
        match &lit.storage {
            Storage::I32(v) => Ok(v.clone()),
            _ => Err(Error::msg("literal is not i32")),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host vector.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], storage: T::store(data.to_vec()) }
    }

    /// A tuple literal (what executables with `return_tuple=True` produce).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: Vec::new(), storage: Storage::Tuple(parts) }
    }

    fn numel(&self) -> i64 {
        self.dims.iter().product()
    }

    pub fn element_count(&self) -> usize {
        self.numel().max(0) as usize
    }

    /// Same data, new dimensions (element counts must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n != self.numel() {
            return Err(Error(format!(
                "cannot reshape {} elements to {dims:?}",
                self.numel()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), storage: self.storage.clone() })
    }

    pub fn shape(&self) -> Result<Shape> {
        let ty = match &self.storage {
            Storage::F32(_) => ElementType::F32,
            Storage::I32(_) => ElementType::S32,
            Storage::Tuple(parts) => {
                return Ok(Shape::Tuple(
                    parts.iter().map(Literal::shape).collect::<Result<_>>()?,
                ))
            }
        };
        Ok(Shape::Array(ArrayShape { dims: self.dims.clone(), ty }))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::read(self)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.storage {
            Storage::Tuple(parts) => Ok(parts.clone()),
            _ => Err(Error::msg("literal is not a tuple")),
        }
    }
}

/// Parsed HLO module text.  The stub validates just enough structure (an
/// `HloModule` header and an `ENTRY` computation) to distinguish real HLO
/// text from garbage at artifact-load time.
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading {path}: {e}")))?;
        Self::from_text(&text)
    }

    pub fn from_text(text: &str) -> Result<HloModuleProto> {
        if !text.trim_start().starts_with("HloModule") {
            return Err(Error::msg("not HLO text: missing HloModule header"));
        }
        if !text.contains("ENTRY") {
            return Err(Error::msg("not HLO text: missing ENTRY computation"));
        }
        Ok(HloModuleProto { text: text.to_string() })
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

/// An HLO computation ready to compile.
pub struct XlaComputation {
    _hlo_bytes: usize,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _hlo_bytes: proto.text.len() }
    }
}

/// PJRT client handle.  The stub's "device" accepts compilations (so
/// artifact loading and registry logic is exercised) but refuses execution.
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: "stub-cpu" })
    }

    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable {})
    }
}

pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    /// The stub cannot execute HLO — callers get a clear, contextual error
    /// instead of wrong numbers.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::msg(
            "the in-tree xla stub cannot execute HLO; link the real xla \
             crate to run AOT artifacts (see DESIGN.md §Runtime)",
        ))
    }
}

pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_vec1_reshape_readback() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = lit.reshape(&[2, 3]).unwrap();
        match r.shape().unwrap() {
            Shape::Array(a) => {
                assert_eq!(a.dims(), &[2, 3]);
                assert_eq!(a.element_type(), ElementType::F32);
            }
            other => panic!("expected array shape, got {other:?}"),
        }
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn literal_i32_and_tuple() {
        let a = Literal::vec1(&[1i32, -2, 3]);
        assert_eq!(a.to_vec::<i32>().unwrap(), vec![1, -2, 3]);
        let t = Literal::tuple(vec![a.clone(), Literal::vec1(&[0.5f32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0], a);
        assert!(a.to_tuple().is_err());
        assert!(matches!(t.shape().unwrap(), Shape::Tuple(ref s) if s.len() == 2));
    }

    #[test]
    fn hlo_text_validation() {
        assert!(HloModuleProto::from_text("HloModule m\n\nENTRY main { }").is_ok());
        assert!(HloModuleProto::from_text("HloModule definitely not valid !!!").is_err());
        assert!(HloModuleProto::from_text("not hlo at all").is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }

    #[test]
    fn client_compiles_but_refuses_to_execute() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "stub-cpu");
        let proto = HloModuleProto::from_text("HloModule m\nENTRY e {}").unwrap();
        let exe = client.compile(&XlaComputation::from_proto(&proto)).unwrap();
        let err = exe.execute::<Literal>(&[]).unwrap_err();
        assert!(err.to_string().contains("stub"), "unhelpful: {err}");
    }
}
