//! The headline end-to-end driver: two-phase BERT pretraining with LANS,
//! exactly the paper's recipe at laptop scale.
//!
//!   phase 1: short sequences (seq 64 here / 128 in the paper), large batch,
//!            Table-1 stage-1 schedule (warmup 42.65%, const 27.35%)
//!   phase 2: long sequences (seq 128 here / 512), ~1/3 batch, resumed from
//!            the phase-1 checkpoint, Table-1 stage-2 schedule
//!            (warmup 19.2%, const 10.8%), step ratio 782/3519
//!
//! Workers run on disjoint shards (§3.4); gradients are combined with a real
//! ring allreduce; the LANS update is bit-checked elsewhere against the
//! Pallas artifact.  Loss curves land in target/pretrain_phase{1,2}.tsv and
//! the run is recorded in EXPERIMENTS.md.
//!
//!     make artifacts-phase2 && cargo run --release --example pretrain_bert
//!     # optional: pretrain_bert <phase1_steps> (default 150)

use anyhow::Result;
use lans::config::{DataConfig, FlightConfig, MetricsConfig, OptBackend, TrainConfig};
use lans::coordinator::{TrainStatus, Trainer};
use lans::optim::Hyper;
use lans::precision::{DType, LossScale};
use lans::runtime::Engine;
use lans::topology::Topology;

fn main() -> Result<()> {
    let p1_meta = std::path::PathBuf::from("artifacts/bert-tiny_s64_b4.meta.json");
    let p2_meta = std::path::PathBuf::from("artifacts/bert-tiny_s128_b1.meta.json");
    if !p1_meta.exists() {
        anyhow::bail!("run `make artifacts` first");
    }
    let phase1_steps: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse().unwrap())
        .unwrap_or(150);
    // the paper's 782/3519 step ratio
    let phase2_steps: u64 = ((phase1_steps as f64) * 782.0 / 3519.0).round() as u64;

    let engine = Engine::cpu()?;
    let data = DataConfig {
        source: "synthetic".into(),
        vocab: 2048,
        corpus_tokens: 128 * 1200,
        seed: 7,
    };
    let ckpt = std::path::PathBuf::from("target/pretrain_phase1.ckpt");

    // ---- phase 1 ----------------------------------------------------------
    let cfg1 = TrainConfig {
        meta_path: p1_meta,
        optimizer: "lans".into(),
        backend: OptBackend::Native,
        workers: 4,
        threads: 0,
        // phase 1 runs the sharded-optimizer path (ZeRO-1): reduce-scatter,
        // owned-shard LANS update, parameter all-gather — bit-identical to
        // the replicated update it replaces
        shard_optimizer: true,
        resume_opt_state: false,
        topology: Topology::flat(4),
        grad_dtype: DType::F32,
        intra_dtype: DType::F32,
        loss_scale: LossScale::Off,
        bucket_mb: 0,
        overlap: true,
        relaxed_collectives: false,
        global_batch: 32,
        steps: phase1_steps,
        seed: 42,
        eval_every: 25,
        eval_batches: 4,
        hyper: Hyper::default(),
        schedule: TrainConfig::paper_stage1_schedule(0.05, phase1_steps),
        data: data.clone(),
        checkpoint: Some(ckpt.clone()),
        resume_from: None,
        curve_out: Some("target/pretrain_phase1.tsv".into()),
        trace: None,
        // run-health telemetry (DESIGN.md §12): phase 1 writes the per-step
        // JSONL + report and prints the human summary below
        metrics: MetricsConfig {
            jsonl: Some("target/pretrain_phase1_metrics.jsonl".into()),
            report: Some("target/pretrain_phase1_report.json".into()),
            ..MetricsConfig::default()
        },
        stop_on_divergence: true,
        flight: FlightConfig::default(),
        inject_failure: None,
    };
    let mut t1 = Trainer::with_engine(cfg1, engine.clone())?;
    println!(
        "=== phase 1: seq {}, effective batch {}, {} steps (stage-1 schedule) ===",
        t1.meta().seq,
        t1.effective_batch(),
        phase1_steps
    );
    let r1 = t1.run()?;
    assert_eq!(r1.status, TrainStatus::Completed, "phase 1 diverged");
    let p1_first = r1.recorder.records.first().unwrap().loss;
    println!(
        "phase 1 done: loss {:.4} -> {:.4} | eval {:.4} | {:.0} tok/s\n",
        p1_first,
        r1.recorder.last_loss().unwrap(),
        r1.final_eval_loss.unwrap(),
        r1.recorder.tokens_per_second()
    );
    let p1_rep = r1.metrics.as_ref().expect("phase-1 metrics knobs set");
    assert_eq!(p1_rep.steps, phase1_steps, "report step count vs run");
    println!("{}", lans::metrics::export::render_summary(p1_rep));

    // ---- phase 2 ----------------------------------------------------------
    if !p2_meta.exists() {
        println!(
            "phase-2 artifact missing (make artifacts-phase2) — stopping after phase 1"
        );
        return Ok(());
    }
    let cfg2 = TrainConfig {
        meta_path: p2_meta,
        optimizer: "lans".into(),
        backend: OptBackend::Native,
        workers: 4,
        threads: 0,
        // phase 2 warm-starts params only (the two-phase convention: the
        // seq-128 moments do not transfer to the seq-512 geometry)
        shard_optimizer: true,
        resume_opt_state: false,
        topology: Topology::flat(4),
        grad_dtype: DType::F32,
        intra_dtype: DType::F32,
        loss_scale: LossScale::Off,
        bucket_mb: 0,
        overlap: true,
        relaxed_collectives: false,
        // paper: phase-2 batch ≈ phase-1/3 (96K -> 33K)
        global_batch: 12,
        steps: phase2_steps.max(5),
        seed: 43,
        eval_every: 10,
        eval_batches: 4,
        hyper: Hyper::default(),
        schedule: TrainConfig::paper_stage2_schedule(0.037, phase2_steps.max(5)),
        data,
        checkpoint: None,
        resume_from: Some(ckpt),
        curve_out: Some("target/pretrain_phase2.tsv".into()),
        trace: None,
        metrics: MetricsConfig::default(),
        stop_on_divergence: true,
        flight: FlightConfig::default(),
        inject_failure: None,
    };
    let mut t2 = Trainer::with_engine(cfg2, engine)?;
    println!(
        "=== phase 2: seq {}, effective batch {}, {} steps (stage-2 schedule, warm-started) ===",
        t2.meta().seq,
        t2.effective_batch(),
        phase2_steps.max(5)
    );
    let r2 = t2.run()?;
    assert_eq!(r2.status, TrainStatus::Completed, "phase 2 diverged");
    println!(
        "phase 2 done: loss {:.4} -> {:.4} | eval {:.4}",
        r2.recorder.records.first().unwrap().loss,
        r2.recorder.last_loss().unwrap(),
        r2.final_eval_loss.unwrap()
    );
    println!(
        "\ntwo-phase pretraining complete; curves in target/pretrain_phase*.tsv"
    );
    // the warm start must carry over: phase-2 initial loss far below scratch
    let p2_first = r2.recorder.records.first().unwrap().loss;
    assert!(
        p2_first < p1_first - 1.0,
        "phase 2 did not inherit phase-1 progress ({p2_first:.3} vs scratch {p1_first:.3})"
    );
    Ok(())
}
