//! §3.4 reproduction: gradient variance under sampling with vs without
//! replacement, and its downstream effect on an actual optimization run.
//!
//!     cargo run --release --example variance_study

use lans::data::{make_shards, WithReplacementSampler};
use lans::optim::{make_optimizer, BlockTable, Hyper};
use lans::util::bench::Table;
use lans::util::rng::Rng;
use lans::variance::{sweep, GradientPopulation};

fn main() {
    // Part 1 — the variance law itself
    let n = 4096;
    let pop = GradientPopulation::synthetic(n, 16, 1);
    println!("# minibatch-mean gradient variance (n = {n}, sigma^2 = {:.3})\n", pop.sigma2);
    let ks = [16, 64, 256, 1024, 2048, 4096];
    let mut table = Table::new(&[
        "k",
        "with-repl emp",
        "sigma^2/k",
        "without-repl emp",
        "(n-k)/(k(n-1))s^2",
        "ratio wo/with",
    ]);
    for row in sweep(&pop, &ks, 4000, 7) {
        table.row(&[
            row.k.to_string(),
            format!("{:.3e}", row.with_repl_empirical),
            format!("{:.3e}", row.with_repl_theory),
            format!("{:.3e}", row.without_repl_empirical),
            format!("{:.3e}", row.without_repl_theory),
            format!(
                "{:.3}",
                row.without_repl_empirical / row.with_repl_empirical.max(1e-300)
            ),
        ]);
    }
    table.print();
    println!(
        "\nNote: without-replacement variance hits exactly 0 at k = n; \
         with-replacement stays at sigma^2/n."
    );

    // Part 2 — effect on optimization: same LANS run fed by sharded
    // without-replacement batches vs with-replacement batches
    println!("\n# downstream effect: LANS on a least-squares problem, k=64 of n=512\n");
    let dim = 32;
    let mut rng = Rng::new(3);
    let w_true: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
    let xs: Vec<Vec<f32>> = (0..512)
        .map(|_| (0..dim).map(|_| rng.normal_f32()).collect())
        .collect();
    let ys: Vec<f32> = xs
        .iter()
        .map(|x| x.iter().zip(&w_true).map(|(a, b)| a * b).sum::<f32>())
        .collect();
    let grad = |w: &[f32], idx: &[usize]| -> Vec<f32> {
        let mut g = vec![0.0f32; dim];
        for &i in idx {
            let e: f32 =
                xs[i].iter().zip(w).map(|(a, b)| a * b).sum::<f32>() - ys[i];
            for (gj, xj) in g.iter_mut().zip(&xs[i]) {
                *gj += e * xj / idx.len() as f32;
            }
        }
        g
    };
    let loss = |w: &[f32]| -> f64 {
        xs.iter()
            .zip(&ys)
            .map(|(x, y)| {
                let e = x.iter().zip(w).map(|(a, b)| a * b).sum::<f32>() - y;
                (e as f64) * (e as f64)
            })
            .sum::<f64>()
            / xs.len() as f64
    };

    let table_b = BlockTable::new(&[("w".into(), dim, false)]);
    let hp = Hyper { weight_decay: 0.0, ..Default::default() };
    let steps = 400;

    let mut shard = make_shards(512, 1, 9).remove(0);
    let mut wr = WithReplacementSampler::new(512, 9);
    let mut runs: Vec<(&str, f64)> = Vec::new();
    for mode in ["without-replacement (sharded)", "with-replacement"] {
        let mut opt = make_optimizer("lans", table_b.clone(), hp).unwrap();
        let mut w = vec![0.0f32; dim];
        for t in 1..=steps {
            let idx = if mode.starts_with("without") {
                shard.next_batch(64)
            } else {
                wr.next_batch(64)
            };
            let g = grad(&w, &idx);
            opt.step(&mut w, &g, 0.05 * (1.0 - t as f32 / steps as f32));
        }
        runs.push((mode, loss(&w)));
    }
    for (mode, l) in &runs {
        println!("  {mode:<32} final mse = {l:.3e}");
    }
    println!(
        "\nwithout/with final-loss ratio = {:.3} (<1 expected: lower gradient \
         variance => better progress at the same step budget)",
        runs[0].1 / runs[1].1
    );
}
