//! Scaling study: the cluster time model across batch sizes, node counts
//! and schedules — how Table 2's 54 minutes decomposes, and what the
//! sqrt-scaling LR rule (§3.3) implies for each batch size.
//!
//!     cargo run --release --example scaling_study

use lans::cluster::{table2_runs, ClusterSpec, Phase, Run, BERT_LARGE};
use lans::optim::sqrt_scaled_lr;
use lans::util::bench::Table;

fn main() {
    println!("# Table 2 decomposition\n");
    let mut t = Table::new(&["run", "phase", "steps", "batch", "seq", "s/step", "minutes"]);
    for run in table2_runs() {
        for (i, p) in run.phases.iter().enumerate() {
            let st = run.cluster.step_time_s(&BERT_LARGE, p.batch_seqs, p.seq, p.slots);
            t.row(&[
                run.label.to_string(),
                format!("{}", i + 1),
                p.steps.to_string(),
                format!("{}K", p.batch_seqs / 1024),
                p.seq.to_string(),
                format!("{st:.2}"),
                format!("{:.1}", p.steps as f64 * st / 60.0),
            ]);
        }
        t.row(&[
            run.label.to_string(),
            "total".into(),
            run.total_steps().to_string(),
            "-".into(),
            "-".into(),
            "-".into(),
            format!("{:.1}", run.total_minutes(&BERT_LARGE)),
        ]);
    }
    t.print();

    println!("\n# sqrt LR scaling (§3.3): eta = sqrt(k) * eta_ref, ref 32K @ 0.005\n");
    let mut t2 = Table::new(&["batch", "sqrt-scaled eta", "paper's choice", "note"]);
    for (k, choice, note) in [
        (32768usize, "0.005", "reference (LAMB 32K)"),
        (65536, "0.0070 (used, slight drop)", "linear scaling still holds"),
        (98304, "0.00675 (Table 1)", "sqrt rule now exceeds the max usable LR"),
        (131072, "diverges", "0.01 > 1/L ceiling — motivates eq. (9)"),
    ] {
        t2.row(&[
            format!("{}K", k / 1024),
            format!("{:.5}", sqrt_scaled_lr(0.005, 32768, k)),
            choice.to_string(),
            note.to_string(),
        ]);
    }
    t2.print();

    println!("\n# phase-1 step-time decomposition vs node count (96K, seq 128)\n");
    let mut t3 = Table::new(&["nodes", "compute s", "comm s (exposed)", "step s", "phase-1 min"]);
    for nodes in [48, 96, 192, 384] {
        let c = ClusterSpec::p3dn(nodes);
        let full = c.step_time_s(&BERT_LARGE, 98304, 128, 20);
        let mut no_comm = c.clone();
        no_comm.overlap = 1.0;
        let comp = no_comm.step_time_s(&BERT_LARGE, 98304, 128, 20);
        t3.row(&[
            nodes.to_string(),
            format!("{comp:.2}"),
            format!("{:.3}", full - comp),
            format!("{full:.2}"),
            format!("{:.1}", 3519.0 * full / 60.0),
        ]);
    }
    t3.print();

    println!("\n# token budget comparison (Table 2's last observation)\n");
    // "when trained with 4301 steps, the sqrt rule suggests 128K/64K —
    //  LANS reaches target with 96K/33K, reducing total work"
    let seqs_lans: u64 = 3519 * 98304 + 782 * 33792;
    let seqs_sqrt: u64 = 3519 * 131072 + 782 * 65536;
    let run_sqrt = Run {
        label: "hypothetical sqrt-rule 128K/64K",
        cluster: ClusterSpec::p3dn(192),
        phases: vec![
            Phase { steps: 3519, batch_seqs: 131072, seq: 128, slots: 20 },
            Phase { steps: 782, batch_seqs: 65536, seq: 512, slots: 80 },
        ],
    };
    println!(
        "LANS 96K/33K:            {:>6.1} Gseq  -> {:.1} modeled minutes",
        seqs_lans as f64 / 1e9,
        table2_runs()[1].total_minutes(&BERT_LARGE)
    );
    println!(
        "sqrt-rule 128K/64K:      {:>6.1} Gseq  -> {:.1} modeled minutes \
         (and diverges per the paper)",
        seqs_sqrt as f64 / 1e9,
        run_sqrt.total_minutes(&BERT_LARGE)
    );
    println!(
        "work saved by the smaller batches: {:.0}%",
        (1.0 - seqs_lans as f64 / seqs_sqrt as f64) * 100.0
    );
}
