//! Internal calibration tool: sweep (optimizer × eta) on short bert-tiny
//! runs to locate the LR where LAMB degrades but LANS holds (used to pick
//! the constants in benches/table2_convergence.rs).

use anyhow::Result;
use lans::config::{DataConfig, FlightConfig, MetricsConfig, OptBackend, TrainConfig};
use lans::coordinator::{TrainStatus, Trainer};
use lans::optim::{from_ratios, Hyper};
use lans::precision::{DType, LossScale};
use lans::topology::Topology;
use lans::runtime::Engine;

fn main() -> Result<()> {
    let meta = std::path::PathBuf::from("artifacts/bert-tiny_s64_b4.meta.json");
    let engine = Engine::cpu()?;
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args.first().map(|s| s.parse().unwrap()).unwrap_or(40);
    let batch: usize = args.get(1).map(|s| s.parse().unwrap()).unwrap_or(32);

    for eta in [0.02, 0.05, 0.1, 0.2, 0.4] {
        for opt in ["lans", "lamb"] {
            let cfg = TrainConfig {
                meta_path: meta.clone(),
                optimizer: opt.into(),
                backend: OptBackend::Native,
                workers: 4,
                threads: 0,
                shard_optimizer: false,
                resume_opt_state: false,
                topology: Topology::flat(4),
                grad_dtype: DType::F32,
                intra_dtype: DType::F32,
                loss_scale: LossScale::Off,
                bucket_mb: 0,
                overlap: true,
                relaxed_collectives: false,
                global_batch: batch,
                steps,
                seed: 1,
                eval_every: 0,
                eval_batches: 2,
                hyper: Hyper::default(),
                schedule: from_ratios(eta, steps, 0.4265, 0.2735),
                data: DataConfig {
                    source: "synthetic".into(),
                    vocab: 2048,
                    corpus_tokens: 64 * 800,
                    seed: 7,
                },
                checkpoint: None,
                resume_from: None,
                curve_out: None,
                trace: None,
                metrics: MetricsConfig::default(),
                stop_on_divergence: false,
                flight: FlightConfig::default(),
                inject_failure: None,
            };
            let mut tr = Trainer::with_engine(cfg, engine.clone())?;
            let rep = tr.run()?;
            println!(
                "eta {eta:<5} {opt:<5} batch {batch:<4} steps {steps:<4} -> ema {:.4} final {:.4} eval {:.4} {:?}",
                rep.recorder.ema_loss().unwrap_or(f64::NAN),
                rep.recorder.last_loss().unwrap_or(f64::NAN),
                rep.final_eval_loss.unwrap_or(f64::NAN),
                rep.status == TrainStatus::Completed
            );
        }
    }
    Ok(())
}
