//! Fig. 1 reproduction: the three learning-rate schedules and their
//! area-under-curve gaps, plus an ASCII rendering of the figure.
//!
//!     cargo run --release --example schedule_explorer

use lans::optim::Schedule;

const T: u64 = 3519;
const TW: u64 = 1500;
const TC: u64 = 963;

fn render(curves: &[(&str, Vec<f64>)], width: usize, height: usize) {
    let max = curves
        .iter()
        .flat_map(|(_, c)| c.iter())
        .fold(0.0f64, |a, &b| a.max(b));
    let mut grid = vec![vec![' '; width]; height];
    for (ci, (_, curve)) in curves.iter().enumerate() {
        let mark = ['*', '+', 'o'][ci % 3];
        for (i, &y) in curve.iter().enumerate() {
            let x = i * (width - 1) / (curve.len() - 1);
            let row = ((1.0 - y / max) * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][x] = mark;
        }
    }
    println!("lr (max {max:.4})");
    for row in grid {
        println!("|{}", row.into_iter().collect::<String>());
    }
    println!("+{}", "-".repeat(width));
    println!(" step 1 .. {T}");
}

fn main() {
    let ideal = Schedule::LinearWarmupDecay { eta: 0.01, t_warmup: TW, t_total: T };
    let small = Schedule::LinearWarmupDecay { eta: 0.007, t_warmup: TW, t_total: T };
    let ours = Schedule::WarmupConstDecay {
        eta: 0.007,
        t_warmup: TW,
        t_const: TC,
        t_total: T,
    };

    println!("# Fig. 1 — LR schedules (T={T}, T_warmup={TW}, T_const={TC})\n");
    let sample = |s: &Schedule| -> Vec<f64> {
        (1..=T).step_by(32).map(|t| s.lr(t)).collect()
    };
    render(
        &[
            ("eq8 eta=0.01", sample(&ideal)),
            ("eq8 eta=0.007", sample(&small)),
            ("eq9 eta=0.007", sample(&ours)),
        ],
        96,
        20,
    );
    println!("\n  *  eq. (8)  eta=0.010   (ideal sqrt-scaled rate — diverges in practice)");
    println!("  +  eq. (8)  eta=0.007   (safe rate, linear decay only)");
    println!("  o  eq. (9)  eta=0.007   (safe rate + constant stage — the paper's scheduler)\n");

    let a_ideal = ideal.area_under_curve(T);
    let a_small = small.area_under_curve(T);
    let a_ours = ours.area_under_curve(T);
    println!("area under curve:");
    println!("  eq8@0.010 = {a_ideal:9.2}");
    println!("  eq8@0.007 = {a_small:9.2}   gap = {:5.2}  (paper: 5.28)", a_ideal - a_small);
    println!("  eq9@0.007 = {a_ours:9.2}   gap = {:5.2}  (paper: 1.91)", a_ideal - a_ours);

    // Table 1: the paper's ratio parameterisation for both stages
    println!("\n# Table 1 — LANS hyper-parameters");
    println!("stage 1: eta=0.00675  ratio_warmup=42.65%  ratio_const=27.35%  (T=3519)");
    println!("stage 2: eta=0.005    ratio_warmup=19.2%   ratio_const=10.8%   (T=782)");
    for (eta, rw, rc, total) in
        [(0.00675, 0.4265, 0.2735, 3519u64), (0.005, 0.192, 0.108, 782)]
    {
        let s = lans::optim::from_ratios(eta, total, rw, rc);
        if let Schedule::WarmupConstDecay { t_warmup, t_const, .. } = s {
            println!(
                "  -> T_warmup={t_warmup} T_const={t_const} \
                 (warmup+const = {:.1}% of stage)",
                (t_warmup + t_const) as f64 / total as f64 * 100.0
            );
        }
    }
}
