//! §4 finetuning flow: "For finetuning, we use AdamW optimizer with
//! per-block gradient normalization (4)."
//!
//! Analogue of the paper's SQuAD step: pretrain on the synthetic corpus
//! (or reuse the checkpoint from `pretrain_bert` if present), then
//! finetune on *fresh documents of the same language* (new generation seed,
//! same Markov transition table — as SQuAD is new text over the English
//! BERT pretrained on) with `adamw_bgn` at a small LR, and show the
//! transfer: the warm start beats a from-scratch run on the same budget.
//!
//!     cargo run --release --example finetune

use anyhow::Result;
use lans::config::{DataConfig, FlightConfig, MetricsConfig, OptBackend, TrainConfig};
use lans::coordinator::{TrainStatus, Trainer};
use lans::optim::{Hyper, Schedule};
use lans::precision::{DType, LossScale};
use lans::topology::Topology;
use lans::runtime::Engine;

fn main() -> Result<()> {
    let meta = std::path::PathBuf::from("artifacts/bert-tiny_s64_b4.meta.json");
    if !meta.exists() {
        anyhow::bail!("run `make artifacts` first");
    }
    let engine = Engine::cpu()?;
    let ckpt = std::path::PathBuf::from("target/pretrain_phase1.ckpt");

    // ensure a pretrained checkpoint exists (short pretrain if needed)
    if !ckpt.exists() {
        println!("no pretrain checkpoint found — running a 60-step pretrain…");
        let cfg = TrainConfig {
            meta_path: meta.clone(),
            optimizer: "lans".into(),
            backend: OptBackend::Native,
            workers: 4,
            threads: 0,
            // exercise the ZeRO-1 path: bit-identical to replicated, with
            // per-worker moments cut 4x
            shard_optimizer: true,
            resume_opt_state: false,
            topology: Topology::flat(4),
            grad_dtype: DType::F32,
            intra_dtype: DType::F32,
            loss_scale: LossScale::Off,
            bucket_mb: 0,
            overlap: true,
            relaxed_collectives: false,
            global_batch: 32,
            steps: 60,
            seed: 42,
            eval_every: 0,
            eval_batches: 2,
            hyper: Hyper::default(),
            schedule: TrainConfig::paper_stage1_schedule(0.05, 60),
            data: DataConfig {
                source: "synthetic".into(),
                vocab: 2048,
                corpus_tokens: 64 * 1200,
                seed: 0x700, // language 7, document stream 0
            },
            checkpoint: Some(ckpt.clone()),
            resume_from: None,
            curve_out: None,
            trace: None,
            metrics: MetricsConfig::default(),
            stop_on_divergence: true,
            flight: FlightConfig::default(),
            inject_failure: None,
        };
        let rep = Trainer::with_engine(cfg, engine.clone())?.run()?;
        assert_eq!(rep.status, TrainStatus::Completed);
    }

    // finetune on fresh documents of the pretraining language — the
    // downstream-task analogue (SQuAD is new text over the same English
    // BERT pretrained on) — with the paper's finetuning optimizer
    // (adamw + eq. 4), small LR, short horizon
    let finetune_cfg = |resume: Option<std::path::PathBuf>| TrainConfig {
        meta_path: meta.clone(),
        optimizer: "adamw_bgn".into(),
        backend: OptBackend::Native,
        workers: 2,
        threads: 0,
        shard_optimizer: false, // adamw_bgn is element-wise; nothing to shard
        resume_opt_state: false,
        topology: Topology::flat(2),
        grad_dtype: DType::F32,
        intra_dtype: DType::F32,
        loss_scale: LossScale::Off,
        bucket_mb: 0,
        overlap: true,
        relaxed_collectives: false,
        global_batch: 8,
        steps: 40,
        seed: 9,
        eval_every: 0,
        eval_batches: 4,
        hyper: Hyper { weight_decay: 0.01, ..Default::default() },
        schedule: Schedule::LinearWarmupDecay {
            eta: 3e-3,
            t_warmup: 4,
            t_total: 40,
        },
        data: DataConfig {
            source: "synthetic".into(),
            vocab: 2048,
            corpus_tokens: 64 * 300,
            seed: 0x701, // SAME language as pretraining, NEW documents
        },
        checkpoint: None,
        resume_from: resume,
        curve_out: None,
        trace: None,
        metrics: MetricsConfig::default(),
        stop_on_divergence: true,
        flight: FlightConfig::default(),
        inject_failure: None,
    };

    println!("=== finetune (adamw_bgn, §4) from the pretrained checkpoint ===");
    let warm = Trainer::with_engine(finetune_cfg(Some(ckpt)), engine.clone())?
        .run()?;
    println!(
        "warm-started : loss {:.4} -> {:.4} | eval {:.4}",
        warm.recorder.records.first().unwrap().loss,
        warm.recorder.last_loss().unwrap(),
        warm.final_eval_loss.unwrap()
    );

    println!("\n=== control: same finetune from random init ===");
    let cold = Trainer::with_engine(finetune_cfg(None), engine)?.run()?;
    println!(
        "from scratch : loss {:.4} -> {:.4} | eval {:.4}",
        cold.recorder.records.first().unwrap().loss,
        cold.recorder.last_loss().unwrap(),
        cold.final_eval_loss.unwrap()
    );

    let w = warm.final_eval_loss.unwrap();
    let c = cold.final_eval_loss.unwrap();
    println!(
        "\ntransfer gain: {:.3} nats ({:.1}% lower eval loss) — pretraining \
         carries to the downstream task",
        c - w,
        (1.0 - w / c) * 100.0
    );
    assert!(w < c, "warm start must beat cold start");
    Ok(())
}
