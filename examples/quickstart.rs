//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Loads the bert-tiny AOT artifacts, trains for 40 steps with LANS on the
//! embedded real-text corpus across 2 simulated workers, prints the loss
//! curve, and evaluates on the held-out shard.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use lans::config::{DataConfig, FlightConfig, MetricsConfig, OptBackend, TrainConfig};
use lans::coordinator::Trainer;
use lans::optim::{Hyper, Schedule};
use lans::precision::{DType, LossScale};
use lans::topology::Topology;

fn main() -> Result<()> {
    let meta = std::path::PathBuf::from("artifacts/bert-tiny_s64_b4.meta.json");
    if !meta.exists() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }

    // CI smoke budget (examples-smoke job): cap the run without editing code
    let steps: u64 = std::env::var("LANS_SMOKE_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(40);

    let cfg = TrainConfig {
        meta_path: meta,
        optimizer: "lans".into(),
        backend: OptBackend::Native,
        workers: 2,
        threads: 0, // auto: block-parallel update + chunk-parallel allreduce
        shard_optimizer: false,
        resume_opt_state: false,
        topology: Topology::flat(2),
        grad_dtype: DType::F32,
        intra_dtype: DType::F32,
        loss_scale: LossScale::Off,
        bucket_mb: 0,
        overlap: true,
        relaxed_collectives: false,
        global_batch: 16,
        steps,
        seed: 42,
        eval_every: 10,
        eval_batches: 4,
        hyper: Hyper::default(),
        schedule: Schedule::WarmupConstDecay {
            eta: 0.02,
            t_warmup: steps / 5,
            t_const: steps * 2 / 5,
            t_total: steps,
        },
        data: DataConfig {
            source: "text".into(),
            vocab: 2048,
            corpus_tokens: 64 * 500,
            seed: 7,
        },
        checkpoint: None,
        resume_from: None,
        curve_out: Some("target/quickstart_curve.tsv".into()),
        trace: None,
        metrics: MetricsConfig::default(),
        stop_on_divergence: true,
        flight: FlightConfig::default(),
        inject_failure: None,
    };

    let mut trainer = Trainer::new(cfg)?;
    println!(
        "quickstart: {} | {} params | effective batch {} sequences",
        trainer.meta().tag,
        trainer.meta().param_count,
        trainer.effective_batch()
    );
    let report = trainer.run()?;

    println!("\nstep   lr        loss     ema");
    for r in report.recorder.records.iter().step_by(5) {
        println!(
            "{:<6} {:.2e}  {:.4}  {:.4}",
            r.step, r.lr, r.loss, r.loss_ema
        );
    }
    println!(
        "\nfinal: loss {:.4} | held-out eval {:.4} | {:.0} tokens/s | curve -> target/quickstart_curve.tsv",
        report.recorder.last_loss().unwrap(),
        report.final_eval_loss.unwrap(),
        report.recorder.tokens_per_second()
    );
    Ok(())
}
