//! Multi-node training on a declared two-tier topology: 8 workers laid out
//! as 2 nodes × 4 gpus, ZeRO-1 sharded optimizer, fp32 intra-node wire and
//! bf16 on the scarce inter-node hops — the paper's 192×8 communication
//! recipe at laptop scale.
//!
//! Demonstrates and asserts the subsystem's two contracts:
//!
//! 1. **Exact bits.**  A short fp32 run on the 2x4 topology finishes with
//!    *bit-identical* parameters to the same run on the flat topology —
//!    the tiered ring keeps the flat ring's per-element reduction order
//!    (DESIGN.md §8), so declaring a topology never changes training.
//! 2. **Accounted bytes.**  The bf16-inter run's executed wire bytes,
//!    split intra/inter, equal the analytic `collective::cost` terms ×
//!    steps, and the inter-node share is 1/gpus_per_node of what the
//!    node-oblivious flat ring would pay.
//!
//!     make artifacts && cargo run --release --example multi_node

use anyhow::Result;
use lans::collective::hierarchical_phase_wire_bytes;
use lans::config::{DataConfig, FailurePoint, FlightConfig, MetricsConfig, OptBackend, TrainConfig};
use lans::coordinator::{TrainStatus, Trainer};
use lans::optim::{Hyper, Schedule};
use lans::precision::{DType, LossScale};
use lans::runtime::Engine;
use lans::topology::{TierPrecision, Topology};

const WORKERS: usize = 8;

fn base_cfg(meta: std::path::PathBuf, topology: Topology, inter: DType, steps: u64) -> TrainConfig {
    TrainConfig {
        meta_path: meta,
        optimizer: "lans".into(),
        backend: OptBackend::Native,
        workers: WORKERS,
        threads: 0,
        // ZeRO-1: the tiered reduce-scatter feeds step_scattered directly
        shard_optimizer: true,
        resume_opt_state: false,
        topology,
        grad_dtype: inter,
        intra_dtype: DType::F32,
        loss_scale: LossScale::Off,
        bucket_mb: 0,
        overlap: true,
        relaxed_collectives: false,
        global_batch: 32,
        steps,
        seed: 42,
        eval_every: 0,
        eval_batches: 4,
        hyper: Hyper::default(),
        schedule: Schedule::WarmupConstDecay {
            eta: 0.02,
            t_warmup: steps / 5,
            t_const: steps * 2 / 5,
            t_total: steps,
        },
        data: DataConfig {
            source: "text".into(),
            vocab: 2048,
            corpus_tokens: 64 * 500,
            seed: 7,
        },
        checkpoint: None,
        resume_from: None,
        curve_out: None,
        trace: None,
        metrics: MetricsConfig::default(),
        stop_on_divergence: true,
        flight: FlightConfig::default(),
        inject_failure: None,
    }
}

fn main() -> Result<()> {
    let meta = std::path::PathBuf::from("artifacts/bert-tiny_s64_b4.meta.json");
    if !meta.exists() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    let engine = Engine::cpu()?;
    let topo = Topology::grid(2, 4);
    let flat = Topology::flat(WORKERS);

    // ---- contract 1: declaring a topology never changes the bits ---------
    println!("=== fp32: flat({WORKERS}) vs {topo} must walk identical trajectories ===");
    let mut t_flat =
        Trainer::with_engine(base_cfg(meta.clone(), flat, DType::F32, 12), engine.clone())?;
    let mut t_grid =
        Trainer::with_engine(base_cfg(meta.clone(), topo, DType::F32, 12), engine.clone())?;
    let r_flat = t_flat.run()?;
    let r_grid = t_grid.run()?;
    assert_eq!(r_flat.status, TrainStatus::Completed);
    assert_eq!(r_grid.status, TrainStatus::Completed);
    for (a, b) in r_flat.params.iter().zip(&r_grid.params) {
        assert_eq!(a.data, b.data, "fp32 topology changed the trajectory");
    }
    println!(
        "bit-identical after 12 steps ✔ (flat inter wire {:.1} MB vs {topo} {:.1} MB)",
        r_flat.wire.inter as f64 / 1e6,
        r_grid.wire.inter as f64 / 1e6
    );

    // ---- contract 1b: and neither does the bucketed step DAG -------------
    // 1 MiB buckets split bert-tiny's gradient into several pipeline stages;
    // the overlapped schedule must still land on the flat run's exact bits
    // and the same per-tier wire bytes (DESIGN.md §9)
    let mut cfg_b = base_cfg(meta.clone(), topo, DType::F32, 12);
    cfg_b.bucket_mb = 1;
    cfg_b.overlap = true;
    let mut t_bkt = Trainer::with_engine(cfg_b, engine.clone())?;
    let r_bkt = t_bkt.run()?;
    assert_eq!(r_bkt.status, TrainStatus::Completed);
    for (a, b) in r_flat.params.iter().zip(&r_bkt.params) {
        assert_eq!(a.data, b.data, "bucketed pipeline changed the trajectory");
    }
    assert_eq!(r_bkt.wire, r_grid.wire, "bucketed wire accounting drifted");
    println!("bucketed+overlapped (1 MiB buckets) bit-identical too ✔");

    // ---- contract 2: the bf16-inter run, end to end -----------------------
    // (bucketed here as well: the pipeline composes with the half wire)
    let steps: u64 = std::env::var("LANS_SMOKE_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(40);
    println!("\n=== {topo} | sharded LANS | fp32 intra / bf16 inter wire | {steps} steps ===");
    let mut cfg2 = base_cfg(meta, topo, DType::Bf16, steps);
    cfg2.bucket_mb = 1;
    // step-trace subsystem: record every span and export a Chrome trace —
    // CI validates the schema with tools/check_trace.py and uploads it
    cfg2.trace = Some("target/multi_node_trace.json".into());
    // run-health telemetry (DESIGN.md §12): per-step JSONL + end-of-run
    // report — CI validates both with tools/check_metrics.py.  The fp32
    // bucketed run above walks the same topology and schedule, so its
    // median step time is the report's measured-vs-model reference.
    cfg2.metrics.jsonl = Some("target/multi_node_metrics.jsonl".into());
    cfg2.metrics.report = Some("target/multi_node_report.json".into());
    cfg2.metrics.model_step_time_s = {
        let deltas = lans::metrics::export::step_wall_deltas(&r_bkt.recorder);
        let m = lans::util::stats::median(&deltas);
        (m > 0.0).then_some(m)
    };
    let mut trainer = Trainer::with_engine(cfg2, engine)?;
    let n_params = trainer.meta().param_count;
    let report = trainer.run()?;
    assert_eq!(report.status, TrainStatus::Completed, "run diverged");

    let first = report.recorder.records.first().unwrap().loss;
    let last = report.recorder.ema_loss().unwrap();
    println!("loss {first:.4} -> {last:.4} (ema) | eval {:.4}", report.final_eval_loss.unwrap());
    assert!(last < first, "loss should improve on the bf16 inter wire");

    // the sharded path executes one tiered reduce-scatter per step; its
    // split byte count must equal the analytic model exactly
    let prec = TierPrecision::half_inter(DType::Bf16);
    let per_step = hierarchical_phase_wire_bytes(&topo, n_params, prec, false);
    assert_eq!(report.wire.intra, per_step.intra * steps, "intra bytes vs model");
    assert_eq!(report.wire.inter, per_step.inter * steps, "inter bytes vs model");

    // and the scarce tier carries ~1/gpus_per_node of the flat ring's load
    let flat_step = hierarchical_phase_wire_bytes(&flat, n_params, prec, false);
    let shrink = flat_step.inter as f64 / per_step.inter as f64;
    println!(
        "wire per step: intra {:.2} MB (fp32 NVLink-tier) + inter {:.2} MB (bf16 NIC-tier); \
         flat would put {:.2} MB on the NICs — {shrink:.2}x more",
        per_step.intra as f64 / 1e6,
        per_step.inter as f64 / 1e6,
        flat_step.inter as f64 / 1e6,
    );
    assert!(
        shrink >= topo.gpus_per_node as f64 * 0.999,
        "inter-node bytes must shrink by ~gpus_per_node ({shrink:.3})"
    );
    println!("\nexecuted bytes == analytic cost model, inter tier cut {shrink:.2}x ✔");

    // ---- step-trace: the overlapped pipeline must actually hide comm ------
    let trace_path = std::path::Path::new("target/multi_node_trace.json");
    assert!(trace_path.exists(), "trace knob set but no Chrome trace written");
    let avail = std::thread::available_parallelism().map_or(1, |n| n.get());
    let best_eff = report
        .recorder
        .records
        .iter()
        .map(|r| r.overlap_eff)
        .fold(0.0f64, f64::max);
    println!(
        "trace written to {} | best per-step overlap efficiency {:.1}% ({avail} threads)",
        trace_path.display(),
        best_eff * 100.0
    );
    if avail >= 4 {
        assert!(
            best_eff > 0.0,
            "overlap on with {avail} threads but no step hid any comm behind compute"
        );
    }

    // ---- run-health telemetry: files written, report internally consistent -
    let rep = report.metrics.as_ref().expect("metrics knobs set but no report");
    assert!(
        std::path::Path::new("target/multi_node_metrics.jsonl").exists(),
        "metrics jsonl knob set but no file written"
    );
    assert!(
        std::path::Path::new("target/multi_node_report.json").exists(),
        "metrics report knob set but no file written"
    );
    assert_eq!(rep.steps, steps, "report step count vs run");
    assert_eq!(rep.skipped_steps, 0, "no scaler configured, nothing to skip");
    // the tiered collectives report their wire split into the registry too;
    // it must agree with the trainer's own executed-bytes ledger
    assert_eq!(
        rep.snapshot.counter("wire.intra_bytes"),
        report.wire.intra,
        "registry intra bytes vs ledger"
    );
    assert_eq!(
        rep.snapshot.counter("wire.inter_bytes"),
        report.wire.inter,
        "registry inter bytes vs ledger"
    );
    println!("\n{}", lans::metrics::export::render_summary(rep));

    // ---- flight recorder: an injected worker failure must seal a bundle ---
    // (DESIGN.md §13) — re-run the grid config with the flight recorder
    // armed and worker 5 rigged to fail mid-run.  The run must abort, and
    // the sealed postmortem bundle must pre-attribute the injected lane.
    // CI validates the bundle with tools/check_postmortem.py and renders it
    // with `lans-inspect postmortem`.
    println!("\n=== flight recorder: injected failure on worker 5 ===");
    let bundle = std::path::PathBuf::from("target/multi_node_postmortem.json");
    let _ = std::fs::remove_file(&bundle); // stale bundle must not mask a miss
    let mut cfg_f = base_cfg(
        std::path::PathBuf::from("artifacts/bert-tiny_s64_b4.meta.json"),
        Topology::grid(2, 4),
        DType::F32,
        12,
    );
    cfg_f.flight = FlightConfig { enabled: true, steps: 8, bundle: Some(bundle.clone()) };
    cfg_f.inject_failure = Some(FailurePoint { step: 6, worker: 5 });
    let mut t_fail = Trainer::with_engine(cfg_f, Engine::cpu()?)?;
    let err = match t_fail.run() {
        Ok(_) => anyhow::bail!("injected failure must abort the run"),
        Err(e) => e,
    };
    assert!(
        format!("{err:#}").contains("injected failure"),
        "abort must cite the injection, got: {err:#}"
    );
    assert!(bundle.exists(), "flight recorder armed but no bundle sealed");

    let bj = lans::util::json::Json::parse(&std::fs::read_to_string(&bundle)?)
        .expect("bundle must be valid JSON");
    assert_eq!(bj.expect("schema").as_str(), Some("lans-postmortem-v1"));
    let trig = bj.expect("trigger");
    assert_eq!(trig.expect("kind").as_str(), Some("worker_failure"));
    assert_eq!(trig.expect("step").as_f64(), Some(6.0));
    let culprit = bj.expect("culprit");
    assert_eq!(
        culprit.expect("lane").as_str(),
        Some("worker-5"),
        "bundle must pre-attribute the injected lane"
    );
    let frames = bj.expect("frames").as_arr().expect("frames array");
    assert!(!frames.is_empty() && frames.len() <= 8, "ring bound violated");
    assert_eq!(
        frames.last().unwrap().expect("step").as_f64(),
        Some(6.0),
        "last retained frame must be the failing step"
    );
    println!(
        "injected failure at step 6 sealed {} ({} frames, culprit worker-5) ✔",
        bundle.display(),
        frames.len()
    );
    Ok(())
}
