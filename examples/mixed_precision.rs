//! Mixed-precision training: fp16 gradient wire + fp32-master LANS with
//! dynamic loss scaling — the paper's 54-minute numerics at laptop scale.
//!
//! The run starts the loss scale absurdly high (2^24) on purpose: the
//! scaled gradients overflow the fp16 wire (max finite value 65504), the
//! optimizer's fused grad² probe sees inf, and the step is *skipped* —
//! parameters, moments and the step clock untouched — while the scale
//! backs off ×1/2.  After a few forced skips the scale lands in range and
//! training proceeds; the Recorder logs every skip and the scale in
//! effect.
//!
//!     make artifacts && cargo run --release --example mixed_precision

use anyhow::Result;
use lans::config::{DataConfig, FlightConfig, MetricsConfig, OptBackend, TrainConfig};
use lans::coordinator::{TrainStatus, Trainer};
use lans::optim::{Hyper, Schedule};
use lans::precision::{DType, LossScale};
use lans::topology::Topology;

fn main() -> Result<()> {
    let meta = std::path::PathBuf::from("artifacts/bert-tiny_s64_b4.meta.json");
    if !meta.exists() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }

    // a taste of the wire format before the run
    println!("wire quantization (f32 -> f16 -> f32):");
    for x in [0.1f32, 1.0, -2.5, 3.0e-8, 7.0e4] {
        println!("  {x:>12.6e} -> {:>12.6e}", DType::F16.round_trip(x));
    }
    println!("(7e4 saturates to inf: that is the overflow loss scaling absorbs)\n");

    // CI smoke budget (examples-smoke job): cap the run without editing code
    let steps: u64 = std::env::var("LANS_SMOKE_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60);
    let cfg = TrainConfig {
        meta_path: meta,
        optimizer: "lans".into(),
        backend: OptBackend::Native,
        workers: 2,
        threads: 0,
        shard_optimizer: false,
        resume_opt_state: false,
        topology: Topology::flat(2),
        // fp16 wire + dynamic loss scaling, deliberately started far too
        // high so the first steps overflow and demonstrate the skip path
        grad_dtype: DType::F16,
        intra_dtype: DType::F32,
        loss_scale: LossScale::Dynamic { init: 16_777_216.0 }, // 2^24
        // bucketed pipeline on the replicated path: overflow probing and
        // the skip/back-off dance run through the step DAG (DESIGN.md §9)
        bucket_mb: 1,
        overlap: true,
        relaxed_collectives: false,
        global_batch: 16,
        steps,
        seed: 42,
        eval_every: 20,
        eval_batches: 4,
        hyper: Hyper::default(),
        schedule: Schedule::WarmupConstDecay {
            eta: 0.02,
            t_warmup: steps / 5,
            t_const: steps * 2 / 5,
            t_total: steps,
        },
        data: DataConfig {
            source: "text".into(),
            vocab: 2048,
            corpus_tokens: 64 * 500,
            seed: 7,
        },
        checkpoint: None,
        resume_from: None,
        curve_out: Some("target/mixed_precision_curve.tsv".into()),
        trace: None,
        metrics: MetricsConfig::default(),
        stop_on_divergence: true,
        flight: FlightConfig::default(),
        inject_failure: None,
    };

    let mut trainer = Trainer::new(cfg)?;
    println!(
        "mixed_precision: {} | fp16 wire | dynamic loss scale from 2^24 | {} steps",
        trainer.meta().tag,
        steps
    );
    let report = trainer.run()?;
    assert_eq!(report.status, TrainStatus::Completed, "run diverged");

    println!("\nstep   scale      loss     note");
    for r in &report.recorder.records {
        if r.skipped || r.step % 10 == 0 || r.step == 1 {
            println!(
                "{:<6} {:<10} {:<8.4} {}",
                r.step,
                r.loss_scale,
                r.loss,
                if r.skipped { "SKIPPED (fp16 overflow, scale backed off)" } else { "" }
            );
        }
    }

    let skipped = report.recorder.skipped_steps();
    let final_scale = report.recorder.records.last().unwrap().loss_scale;
    println!(
        "\n{skipped} skipped steps while the scale walked down from 2^24 to {final_scale}; \
         final loss {:.4}, held-out eval {:.4}",
        report.recorder.last_loss().unwrap(),
        report.final_eval_loss.unwrap(),
    );
    // the demo's point: overflows happened, were absorbed, and training
    // still made progress on the fp32 master weights
    assert!(skipped >= 1, "expected at least one forced-overflow skip");
    let first = report.recorder.records.first().unwrap().loss;
    let last = report.recorder.ema_loss().unwrap();
    assert!(
        last < first,
        "loss should improve despite the skipped steps ({first:.3} -> {last:.3})"
    );
    println!("curve (incl. loss_scale + skipped columns) -> target/mixed_precision_curve.tsv");
    Ok(())
}
